//! The native packed-weight backend: a pure-Rust byte-level transformer
//! forward that executes directly from `engine::PackedModel` layers, with
//! one KV lane per concurrently-decoding sequence.
//!
//! The hot path is `step_lanes`: one decode step advances every active
//! lane by one byte, sweeping each packed linear (6 per block + unembed)
//! *once* across all lanes via `Linear::gemv_batch` — the
//! weight words are fetched once per row and dotted against every lane's
//! activation, so the bit-unpack/weight-traffic cost of 1-bit serving is
//! amortized over the batch. Attention stays per-lane (each lane has its
//! own KV history length). Per-lane arithmetic is identical to the
//! single-lane path, so batched and sequential greedy decoding produce
//! byte-identical outputs — the invariant `tests/serve_gen.rs` pins down.
//!
//! Op-for-op the math mirrors `model::forward` (same rmsnorm, same
//! per-head softmax accumulation order), so a dense-mode engine reproduces
//! the reference logits to float rounding, and a packed-mode engine matches
//! `model::forward` over [`PackedModel::to_weights`] — the invariant the
//! `engine_parity` integration test pins down.

use super::kv::{Arena, KvPool, Lane};
use super::model::PackedModel;
use super::paged::{blocks_for, KvExhausted, PagedKv};
use super::spec::{DraftLane, SpecConfig, SpecRound, SpecStats};
use super::{attend_position, greedy_token, Backend, KvStats};
use crate::data::ByteTokenizer;
use crate::model::{gelu_tanh, rmsnorm};
use anyhow::{ensure, Result};

pub struct NativeBackend {
    model: PackedModel,
    pool: KvPool,
    /// Multi-lane GEMV adjoint scratch, `[n_active * max(d, d_ff)]`.
    zpool: Vec<f32>,
    batch: usize,
    threads: usize,
    /// Paged-KV override from `set_kv_blocks` (blocks, block_len); `None`
    /// components fall back to the worst-case default on pool rebuilds.
    kv_blocks: Option<usize>,
    kv_block_len: Option<usize>,
    /// Speculative decoding config (`set_spec`) + one low-band draft lane
    /// per KV lane, built lazily on the first speculative sweep.
    spec: SpecConfig,
    drafts: Vec<DraftLane>,
    /// Per-position scratch for the multi-position verify sweep, grown on
    /// demand and reused across rounds (one [`Arena`] per in-flight
    /// position across all lanes).
    spec_scratch: Vec<Arena>,
    /// Count of packed-weight sweeps executed (one per `step_lanes` or
    /// `sweep_positions` call) — the unit the batching argument amortizes
    /// over, surfaced for observability ([`NativeBackend::sweeps`]).
    sweeps: u64,
}

/// Per-lane view of one decode position: the lane's paged KV view plus
/// disjoint mutable borrows of every arena buffer, so the batched step can
/// hand (input, output) pairs of *different* lanes to one `gemv_batch`
/// sweep. Reads/writes of the KV rows themselves go through the *shared*
/// block arena, threaded through the step loop separately.
struct LaneStep<'a> {
    kv: &'a mut PagedKv,
    t: usize,
    x: &'a mut [f32],
    h: &'a mut [f32],
    q: &'a mut [f32],
    k: &'a mut [f32],
    v: &'a mut [f32],
    attn: &'a mut [f32],
    proj: &'a mut [f32],
    ff: &'a mut [f32],
    probs: &'a mut [f32],
    logits: &'a mut [f32],
}

impl NativeBackend {
    pub fn new(model: PackedModel, batch: usize) -> NativeBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        NativeBackend::with_threads(model, batch, threads)
    }

    pub fn with_threads(model: PackedModel, batch: usize, threads: usize) -> NativeBackend {
        // every GEMV below (decode, draft, verify) dispatches through the
        // process-wide packed-dot kernel; say which one once per backend
        crate::util::log::info(&format!(
            "native backend: {} threads, gemv kernel {}",
            threads.max(1),
            crate::pack::kernels::active().name
        ));
        let pool = KvPool::new(&model.config, 1);
        NativeBackend {
            pool,
            zpool: Vec::new(),
            model,
            batch: batch.max(1),
            threads: threads.max(1),
            kv_blocks: None,
            kv_block_len: None,
            spec: SpecConfig::disabled(),
            drafts: Vec::new(),
            spec_scratch: Vec::new(),
            sweeps: 0,
        }
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// How many packed-weight sweeps this backend has executed — one per
    /// batched decode step (`step_lanes`) or speculative verify pass
    /// (`sweep_positions`), whatever the number of lanes it served. The
    /// serving layer divides tokens by sweeps to see the batching
    /// amortization; `hbllm_sweep_us` histograms the wall-clock per sweep.
    pub fn sweeps(&self) -> u64 {
        self.sweeps
    }

    /// Rebuild the lane pool for `n` lanes, honoring any `set_kv_blocks`
    /// override (worst-case arena otherwise). Drops all decode state.
    fn rebuild_pool(&mut self, n: usize) {
        let cfg = &self.model.config;
        let (worst_blocks, bl) = KvPool::worst_case_geometry(cfg, n, self.kv_block_len);
        let blocks = self.kv_blocks.unwrap_or(worst_blocks);
        self.pool = KvPool::with_paging(cfg, n, blocks, bl);
        // draft lanes track the pool's lane count; rebuilt lazily (with
        // fresh counters) by the next speculative sweep
        self.drafts.clear();
    }

    /// Advance the given lanes by one byte each: embed `byte` at each
    /// lane's next position, run every block sweeping each linear once
    /// across all lanes, leave each lane's next-token logits in its arena.
    /// `active` must be sorted by lane index, without duplicates.
    fn step_lanes(&mut self, active: &[(usize, u8)]) -> Result<()> {
        if active.is_empty() {
            return Ok(());
        }
        self.sweeps += 1;
        let n_lanes = self.pool.len();
        let NativeBackend { model, pool, zpool, threads, .. } = self;
        let threads = *threads;
        let KvPool { blocks, lanes: pool_lanes } = pool;
        let cfg = &model.config;
        let (d, heads, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();

        // disjoint &mut Lane for the active set (ascending, unique)
        let mut lanes: Vec<&mut Lane> = Vec::with_capacity(active.len());
        {
            let mut rest: &mut [Lane] = pool_lanes;
            let mut consumed = 0usize;
            for &(idx, _) in active {
                ensure!(
                    idx >= consumed,
                    "decode lanes must be sorted and unique (lane {idx})"
                );
                ensure!(idx < n_lanes, "lane {idx} out of range ({n_lanes} lanes)");
                let (head, tail) = rest.split_at_mut(idx - consumed + 1);
                lanes.push(head.last_mut().unwrap());
                consumed = idx + 1;
                rest = tail;
            }
        }

        // embed + per-lane step contexts (growing each lane's block table
        // so its next position is addressable — the one allocation a step
        // may make, and only once per block_len tokens per lane)
        let mut ctxs: Vec<LaneStep> = Vec::with_capacity(lanes.len());
        for (lane, &(_, byte)) in lanes.into_iter().zip(active) {
            ensure!(!lane.kv.is_full(), "kv cache full (seq {})", lane.kv.seq());
            let t = lane.kv.len();
            lane.kv.ensure_pos(blocks, t)?;
            let Lane { kv, arena, .. } = lane;
            let Arena { x, h, q, k, v, attn, proj, ff, probs, logits } = arena;
            let te = model.tok_emb.row(byte as usize);
            let pe = model.pos_emb.row(t);
            for j in 0..d {
                x[j] = te[j] + pe[j];
            }
            ctxs.push(LaneStep {
                kv,
                t,
                x: &mut x[..],
                h: &mut h[..],
                q: &mut q[..],
                k: &mut k[..],
                v: &mut v[..],
                attn: &mut attn[..],
                proj: &mut proj[..],
                ff: &mut ff[..],
                probs: &mut probs[..],
                logits: &mut logits[..],
            });
        }

        for (li, layer) in model.layers.iter().enumerate() {
            // --- attention ---
            for c in ctxs.iter_mut() {
                rmsnorm(c.x, &layer.ln1, c.h);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.h, &mut *c.q)).collect();
                layer.wq.gemv_batch(&mut io, zpool, threads);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.h, &mut *c.k)).collect();
                layer.wk.gemv_batch(&mut io, zpool, threads);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.h, &mut *c.v)).collect();
                layer.wv.gemv_batch(&mut io, zpool, threads);
            }
            for c in ctxs.iter_mut() {
                c.kv.store(blocks, li, c.t, c.k, c.v);
                let LaneStep { kv, t, q, probs, attn, .. } = c;
                let t = *t;
                attend_position(
                    heads,
                    dh,
                    scale,
                    t,
                    q,
                    probs,
                    attn,
                    |u| kv.key(blocks, li, u),
                    |u| kv.val(blocks, li, u),
                );
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.attn, &mut *c.proj)).collect();
                layer.wo.gemv_batch(&mut io, zpool, threads);
            }
            for c in ctxs.iter_mut() {
                for j in 0..d {
                    c.x[j] += c.proj[j];
                }
            }

            // --- MLP ---
            for c in ctxs.iter_mut() {
                rmsnorm(c.x, &layer.ln2, c.h);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.h, &mut *c.ff)).collect();
                layer.w1.gemv_batch(&mut io, zpool, threads);
            }
            for c in ctxs.iter_mut() {
                for vv in c.ff.iter_mut() {
                    *vv = gelu_tanh(*vv);
                }
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.ff, &mut *c.proj)).collect();
                layer.w2.gemv_batch(&mut io, zpool, threads);
            }
            for c in ctxs.iter_mut() {
                for j in 0..d {
                    c.x[j] += c.proj[j];
                }
            }
        }

        for c in ctxs.iter_mut() {
            rmsnorm(c.x, &model.ln_f, c.h);
        }
        {
            let mut io: Vec<(&[f32], &mut [f32])> =
                ctxs.iter_mut().map(|c| (&*c.h, &mut *c.logits)).collect();
            model.unemb.gemv_batch(&mut io, zpool, threads);
        }
        for c in ctxs.iter_mut() {
            c.kv.advance();
        }
        Ok(())
    }

    /// Multi-position verify sweep — the speculative decoder's hot path.
    ///
    /// For each `(lane, bytes, n_tail)` (sorted by lane, `bytes`
    /// non-empty), feed every byte at the lane's next KV positions, but —
    /// unlike the byte-by-byte [`NativeBackend::step_lanes`] loop — run
    /// *all* positions of *all* lanes through each packed linear in one
    /// `gemv_batch`: one fetch of the sign words per layer per round
    /// serves `k + 1` speculative positions (and any owed prefill), which
    /// is the entire economic argument for drafting. Within a layer,
    /// later positions of a lane attend over the K/V rows stored for
    /// earlier positions moments before, in the same pass.
    ///
    /// Per-position arithmetic (embed, rmsnorm, attention accumulation
    /// order, GEMV expression) is identical to `step_lanes`, so each
    /// position's logits row is bit-identical to what byte-by-byte
    /// decoding would produce — the invariant `tests/spec_parity.rs` pins.
    ///
    /// Returns, per lane, the logits rows of its last `n_tail` positions.
    /// KV state is advanced past every fed byte; rejection rollback is the
    /// caller's job (`PagedKv::truncate_to`).
    fn sweep_positions(&mut self, feeds: &[(usize, Vec<u8>, usize)]) -> Result<Vec<Vec<Vec<f32>>>> {
        self.sweeps += 1;
        let n_lanes = self.pool.len();
        let total: usize = feeds.iter().map(|f| f.1.len()).sum();
        while self.spec_scratch.len() < total {
            self.spec_scratch.push(Arena::new(&self.model.config));
        }
        let NativeBackend { model, pool, zpool, spec_scratch, threads, .. } = self;
        let threads = *threads;
        let KvPool { blocks, lanes: pool_lanes } = pool;
        let cfg = &model.config;
        let (d, heads, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();

        // disjoint &mut Lane for the active set (ascending, unique)
        let mut lanes: Vec<&mut Lane> = Vec::with_capacity(feeds.len());
        {
            let mut rest: &mut [Lane] = pool_lanes;
            let mut consumed = 0usize;
            for (idx, _, _) in feeds.iter() {
                let idx = *idx;
                ensure!(
                    idx >= consumed,
                    "spec sweep lanes must be sorted and unique (lane {idx})"
                );
                ensure!(idx < n_lanes, "lane {idx} out of range ({n_lanes} lanes)");
                let (head, tail) = rest.split_at_mut(idx - consumed + 1);
                lanes.push(head.last_mut().unwrap());
                consumed = idx + 1;
                rest = tail;
            }
        }

        // grow each lane's block table to its last fed position, embed
        // every (lane, position) item into its scratch slot
        let scratch = &mut spec_scratch[..total];
        let mut t0s: Vec<usize> = Vec::with_capacity(feeds.len());
        {
            let mut item = 0usize;
            for (fi, (_, bytes, n_tail)) in feeds.iter().enumerate() {
                ensure!(!bytes.is_empty(), "spec sweep with an empty feed");
                ensure!(*n_tail <= bytes.len(), "spec tail longer than the feed");
                let t0 = lanes[fi].kv.len();
                ensure!(
                    t0 + bytes.len() <= lanes[fi].kv.seq(),
                    "spec sweep past the window (pos {} of {})",
                    t0 + bytes.len(),
                    lanes[fi].kv.seq()
                );
                lanes[fi].kv.ensure_pos(blocks, t0 + bytes.len() - 1)?;
                t0s.push(t0);
                for (p, &byte) in bytes.iter().enumerate() {
                    let c = &mut scratch[item];
                    let te = model.tok_emb.row(byte as usize);
                    let pe = model.pos_emb.row(t0 + p);
                    for j in 0..d {
                        c.x[j] = te[j] + pe[j];
                    }
                    item += 1;
                }
            }
        }

        for (li, layer) in model.layers.iter().enumerate() {
            // --- attention projections: all positions, one weight sweep ---
            for c in scratch.iter_mut() {
                rmsnorm(&c.x, &layer.ln1, &mut c.h);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    scratch.iter_mut().map(|c| (&c.h[..], &mut c.q[..])).collect();
                layer.wq.gemv_batch(&mut io, zpool, threads);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    scratch.iter_mut().map(|c| (&c.h[..], &mut c.k[..])).collect();
                layer.wk.gemv_batch(&mut io, zpool, threads);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    scratch.iter_mut().map(|c| (&c.h[..], &mut c.v[..])).collect();
                layer.wv.gemv_batch(&mut io, zpool, threads);
            }
            // --- attention: per lane, per position in order (a position
            // reads the rows its predecessors just stored) ---
            {
                let mut item = 0usize;
                for (fi, (_, bytes, _)) in feeds.iter().enumerate() {
                    let t0 = t0s[fi];
                    for p in 0..bytes.len() {
                        let c = &mut scratch[item];
                        let t = t0 + p;
                        lanes[fi].kv.store(blocks, li, t, &c.k, &c.v);
                        let kv = &lanes[fi].kv;
                        attend_position(
                            heads,
                            dh,
                            scale,
                            t,
                            &c.q,
                            &mut c.probs,
                            &mut c.attn,
                            |u| kv.key(blocks, li, u),
                            |u| kv.val(blocks, li, u),
                        );
                        item += 1;
                    }
                }
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    scratch.iter_mut().map(|c| (&c.attn[..], &mut c.proj[..])).collect();
                layer.wo.gemv_batch(&mut io, zpool, threads);
            }
            for c in scratch.iter_mut() {
                for j in 0..d {
                    c.x[j] += c.proj[j];
                }
            }

            // --- MLP ---
            for c in scratch.iter_mut() {
                rmsnorm(&c.x, &layer.ln2, &mut c.h);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    scratch.iter_mut().map(|c| (&c.h[..], &mut c.ff[..])).collect();
                layer.w1.gemv_batch(&mut io, zpool, threads);
            }
            for c in scratch.iter_mut() {
                for vv in c.ff.iter_mut() {
                    *vv = gelu_tanh(*vv);
                }
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    scratch.iter_mut().map(|c| (&c.ff[..], &mut c.proj[..])).collect();
                layer.w2.gemv_batch(&mut io, zpool, threads);
            }
            for c in scratch.iter_mut() {
                for j in 0..d {
                    c.x[j] += c.proj[j];
                }
            }
        }

        // --- unembed: only the tail positions need logits ---
        for c in scratch.iter_mut() {
            rmsnorm(&c.x, &model.ln_f, &mut c.h);
        }
        {
            let mut tail_mask = vec![false; total];
            let mut item = 0usize;
            for (_, bytes, n_tail) in feeds {
                for p in 0..bytes.len() {
                    tail_mask[item + p] = p >= bytes.len() - n_tail;
                }
                item += bytes.len();
            }
            let mut io: Vec<(&[f32], &mut [f32])> = Vec::with_capacity(total);
            for (j, c) in scratch.iter_mut().enumerate() {
                if tail_mask[j] {
                    io.push((&c.h[..], &mut c.logits[..]));
                }
            }
            model.unemb.gemv_batch(&mut io, zpool, threads);
        }

        // advance past every fed byte and hand back the tail rows
        let mut out = Vec::with_capacity(feeds.len());
        let mut item = 0usize;
        for (fi, (_, bytes, n_tail)) in feeds.iter().enumerate() {
            for _ in 0..bytes.len() {
                lanes[fi].kv.advance();
            }
            let start = item + bytes.len() - n_tail;
            out.push((start..item + bytes.len()).map(|j| scratch[j].logits.clone()).collect());
            item += bytes.len();
        }
        Ok(out)
    }

    fn check_token(&self, tok: i32) -> Result<u8> {
        ensure!(
            (0..self.model.config.vocab as i32).contains(&tok),
            "token {tok} out of byte vocab"
        );
        Ok(tok as u8)
    }

    /// NLL of the next token under lane 0's current logits (same formula as
    /// `model::nll_from_logits`).
    fn nll_of_next(&self, next: u8) -> f32 {
        let row = &self.pool.lanes[0].arena.logits;
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let logz: f32 = maxv + row.iter().map(|&v| (v - maxv).exp()).sum::<f32>().ln();
        logz - row[next as usize]
    }

    fn nll_impl(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s) = (self.batch, self.model.config.seq_len);
        ensure!(tokens.len() == b * s, "expected {}x{} tokens, got {}", b, s, tokens.len());
        let per_row = s - 1;
        let mut out: Vec<f32> = Vec::with_capacity(b * per_row);
        for r in 0..b {
            // eval batches pad by repeating rows; unlike the fixed-shape XLA
            // entry, the sequential engine can just reuse the previous result
            if r > 0 && tokens[r * s..(r + 1) * s] == tokens[(r - 1) * s..r * s] {
                let prev = out.len() - per_row;
                out.extend_from_within(prev..);
                continue;
            }
            self.reset_lane(0);
            for t in 0..s {
                let byte = self.check_token(tokens[r * s + t])?;
                self.step_lanes(&[(0, byte)])?;
                if t + 1 < s {
                    let next = self.check_token(tokens[r * s + t + 1])?;
                    out.push(self.nll_of_next(next));
                }
            }
        }
        Ok(out)
    }

    fn logits_impl(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s, v) = (self.batch, self.model.config.seq_len, self.model.config.vocab);
        ensure!(tokens.len() == b * s, "expected {}x{} tokens, got {}", b, s, tokens.len());
        let mut out: Vec<f32> = Vec::with_capacity(b * s * v);
        for r in 0..b {
            if r > 0 && tokens[r * s..(r + 1) * s] == tokens[(r - 1) * s..r * s] {
                let prev = out.len() - s * v;
                out.extend_from_within(prev..);
                continue;
            }
            self.reset_lane(0);
            for t in 0..s {
                let byte = self.check_token(tokens[r * s + t])?;
                self.step_lanes(&[(0, byte)])?;
                out.extend_from_slice(&self.pool.lanes[0].arena.logits);
            }
        }
        Ok(out)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        "native".to_string()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.model.config.seq_len
    }

    fn vocab(&self) -> usize {
        self.model.config.vocab
    }

    fn lanes(&self) -> usize {
        self.pool.len()
    }

    /// Reallocate the lane pool. Drops all decode state (every lane's KV
    /// view and prefix); the scheduler resets lanes on admission anyway.
    /// A `set_kv_blocks` override survives the rebuild; otherwise the
    /// arena is re-sized to the new lane count's worst case.
    fn set_lanes(&mut self, n: usize) -> usize {
        self.rebuild_pool(n);
        self.pool.len()
    }

    fn kv_stats(&self) -> Option<KvStats> {
        Some(self.pool.stats())
    }

    fn sweeps_executed(&self) -> u64 {
        self.sweeps
    }

    fn set_kv_blocks(
        &mut self,
        n_blocks: Option<usize>,
        block_len: Option<usize>,
    ) -> Option<KvStats> {
        self.kv_blocks = n_blocks;
        self.kv_block_len = block_len;
        self.rebuild_pool(self.pool.len());
        self.kv_stats()
    }

    /// Bump the refcount of every block covering `lane`'s first
    /// `positions` cached positions and hand the block list to the caller
    /// (the serving prompt cache). The blocks now survive the lane's
    /// eviction until `kv_release_blocks` drops them.
    fn kv_retain_prefix(&mut self, lane: usize, positions: usize) -> Option<Vec<usize>> {
        let bl = self.pool.blocks.block_len();
        let l = self.pool.lanes.get(lane)?;
        if positions == 0 || l.kv.len() < positions {
            return None;
        }
        let taken: Vec<usize> = l.kv.block_table()[..blocks_for(positions, bl)].to_vec();
        for &b in &taken {
            self.pool.blocks.retain(b);
        }
        Some(taken)
    }

    fn kv_release_blocks(&mut self, blocks: &[usize]) {
        for &b in blocks {
            self.pool.blocks.release(b);
        }
    }

    /// Reset `lane` and map the retained `blocks` into it read-only at
    /// fill level `positions`, with `prefix` as its consumed text — the
    /// lane's next `decode_batch` then takes the incremental path and
    /// prefills only the bytes beyond the match; its first write into a
    /// shared block copy-on-writes a private clone.
    fn kv_adopt_prefix(
        &mut self,
        lane: usize,
        blocks: &[usize],
        positions: usize,
        prefix: &[u8],
    ) -> bool {
        let bl = self.pool.blocks.block_len();
        if lane >= self.pool.len()
            || positions == 0
            || positions != prefix.len()
            || positions > self.model.config.seq_len
            || blocks.len() < blocks_for(positions, bl)
        {
            return false;
        }
        self.reset_lane(lane);
        let KvPool { blocks: arena, lanes } = &mut self.pool;
        let l = &mut lanes[lane];
        l.kv.share_prefix(arena, blocks, positions);
        l.prefix.extend_from_slice(prefix);
        true
    }

    fn nll(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        // lane 0 is always released, error or not — a failed row (bad
        // token, or KV exhaustion under a deliberately small arena) must
        // not strand blocks the serving scheduler is metering
        let out = self.nll_impl(tokens);
        self.reset_lane(0);
        out
    }

    fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out = self.logits_impl(tokens);
        self.reset_lane(0);
        out
    }

    fn decode_step(&mut self, text: &[u8]) -> Result<Vec<f32>> {
        Ok(self.decode_batch(&[(0, text)])?.pop().unwrap())
    }

    /// Multi-sequence decode: each `(lane, text)` pair is advanced to the
    /// end of its text, incrementally where the lane's cached prefix still
    /// matches. Lanes march in lock step — per sub-step, the next byte of
    /// every lane that still has pending bytes is processed in one
    /// `step_lanes` sweep — so a freshly admitted lane prefills its
    /// prompt while established lanes decode, and the packed-weight sweep
    /// is always shared across whatever is active (continuous batching).
    fn decode_batch(&mut self, reqs: &[(usize, &[u8])]) -> Result<Vec<Vec<f32>>> {
        let s = self.model.config.seq_len;
        const SEED: [u8; 1] = [ByteTokenizer::PAD];
        let mut windows: Vec<&[u8]> = Vec::with_capacity(reqs.len());
        let mut done: Vec<usize> = Vec::with_capacity(reqs.len());
        let mut incremental: Vec<bool> = Vec::with_capacity(reqs.len());
        // plan pass (no mutation): validate the lane set, pick incremental
        // vs re-prefill per lane, and meter the block budget the whole
        // sweep will need — so exhaustion fails *here*, typed and before
        // any lane state is touched, and the scheduler can evict + retry
        let bl = self.pool.blocks.block_len();
        let mut need = 0usize;
        let mut avail = self.pool.blocks.free_blocks();
        for (ri, &(lane, text)) in reqs.iter().enumerate() {
            ensure!(lane < self.pool.len(), "lane {lane} out of range ({} lanes)", self.pool.len());
            ensure!(
                ri == 0 || reqs[ri - 1].0 < lane,
                "decode_batch lanes must be sorted and unique"
            );
            // last `seq` bytes are the visible window; an empty text is
            // seeded with the pad byte so position 0 always exists
            let window: &[u8] = if text.is_empty() {
                &SEED
            } else {
                &text[text.len().saturating_sub(s)..]
            };
            let lane_ref = &self.pool.lanes[lane];
            let keep = lane_ref.prefix.len();
            // incremental only when the cache really holds the recorded
            // prefix (scoring calls share lane 0 and reset it, and a failed
            // nll can leave a partial fill) — otherwise re-prefill
            let inc = lane_ref.kv.len() == keep
                && window.len() >= keep
                && window[..keep] == lane_ref.prefix[..];
            let target = blocks_for(window.len(), bl);
            if inc {
                // pure incremental: only the unseen suffix runs through
                // (saturating: an aborted sweep can leave one block grown
                // past `len`, which simply gets reused), plus one clone if
                // the first write lands in a shared (prefix-cache) block
                need += target.saturating_sub(lane_ref.kv.held_blocks());
                need += lane_ref.kv.pending_cow(&self.pool.blocks);
                done.push(keep);
            } else {
                // window slid (or context switched): re-prefill from
                // scratch — its sole-reference blocks come back to the
                // free list (shared ones stay pinned by their other refs)
                avail += lane_ref.kv.reclaimable_blocks(&self.pool.blocks);
                need += target;
                done.push(0);
            }
            incremental.push(inc);
            windows.push(window);
        }
        if need > avail {
            return Err(KvExhausted { needed: need, free: avail }.into());
        }
        {
            let KvPool { blocks, lanes } = &mut self.pool;
            for (ri, &(lane, _)) in reqs.iter().enumerate() {
                if !incremental[ri] {
                    lanes[lane].kv.clear(blocks);
                }
            }
        }
        // lock-step advance over the pending suffixes
        let mut active: Vec<(usize, u8)> = Vec::with_capacity(reqs.len());
        let mut stepped: Vec<usize> = Vec::with_capacity(reqs.len());
        loop {
            active.clear();
            stepped.clear();
            for (ri, &(lane, _)) in reqs.iter().enumerate() {
                if done[ri] < windows[ri].len() {
                    active.push((lane, windows[ri][done[ri]]));
                    stepped.push(ri);
                }
            }
            if active.is_empty() {
                break;
            }
            self.step_lanes(&active)?;
            for &ri in &stepped {
                done[ri] += 1;
            }
        }
        // commit prefixes + hand back each lane's logits
        let mut out = Vec::with_capacity(reqs.len());
        for (ri, &(lane, _)) in reqs.iter().enumerate() {
            let lane_ref = &mut self.pool.lanes[lane];
            lane_ref.prefix.clear();
            lane_ref.prefix.extend_from_slice(windows[ri]);
            out.push(lane_ref.arena.logits.clone());
        }
        Ok(out)
    }

    fn set_spec(&mut self, cfg: SpecConfig) -> SpecConfig {
        self.spec = SpecConfig { k: cfg.k, enabled: cfg.enabled && cfg.k > 0 };
        self.spec
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        let mut st = SpecStats {
            k: self.spec.k,
            enabled: self.spec.enabled,
            lane_drafted: vec![0; self.pool.len()],
            lane_accepted: vec![0; self.pool.len()],
            ..Default::default()
        };
        for (i, d) in self.drafts.iter().enumerate() {
            st.rounds += d.rounds;
            st.drafted += d.drafted;
            st.accepted += d.accepted;
            st.lane_drafted[i] = d.drafted;
            st.lane_accepted[i] = d.accepted;
            st.draft_kv_bytes += d.kv_bytes();
        }
        Some(st)
    }

    /// Speculative batched decode (the frequency cascade, `engine::spec`):
    /// per `(lane, text)` pair, draft up to `k` bytes with the low-band
    /// forward, verify them — together with any prefill the lane still
    /// owes — in one multi-position sweep of the full packed model, and
    /// return the verified bytes plus accept/reject bookkeeping. Greedy
    /// output is byte-identical to [`NativeBackend::decode_batch`] +
    /// argmax; only the schedule differs.
    ///
    /// Like `decode_batch`, a sweep that cannot fit its worst-case block
    /// budget fails before touching any lane with a typed
    /// [`KvExhausted`]; on draft rejection the lane's `PagedKv` is rolled
    /// back (`truncate_to`), releasing the rejected positions' blocks.
    fn decode_batch_spec(&mut self, reqs: &[(usize, &[u8])], k: usize) -> Result<Vec<SpecRound>> {
        let s = self.model.config.seq_len;
        const SEED: [u8; 1] = [ByteTokenizer::PAD];
        while self.drafts.len() < self.pool.len() {
            self.drafts.push(DraftLane::new(&self.model.config));
        }
        self.drafts.truncate(self.pool.len());

        // plan pass (no mutation): windows, kept prefixes, draft widths
        // (clamped to the window headroom so a round never has to slide),
        // and the sweep's whole block budget — exhaustion fails here,
        // typed, before any lane state is touched
        let bl = self.pool.blocks.block_len();
        let mut need = 0usize;
        let mut avail = self.pool.blocks.free_blocks();
        let mut windows: Vec<&[u8]> = Vec::with_capacity(reqs.len());
        let mut keeps: Vec<usize> = Vec::with_capacity(reqs.len());
        let mut k_effs: Vec<usize> = Vec::with_capacity(reqs.len());
        for (ri, &(lane, text)) in reqs.iter().enumerate() {
            ensure!(lane < self.pool.len(), "lane {lane} out of range ({} lanes)", self.pool.len());
            ensure!(
                ri == 0 || reqs[ri - 1].0 < lane,
                "decode_batch_spec lanes must be sorted and unique"
            );
            let window: &[u8] = if text.is_empty() {
                &SEED
            } else {
                &text[text.len().saturating_sub(s)..]
            };
            let lane_ref = &self.pool.lanes[lane];
            let keep0 = lane_ref.prefix.len();
            let inc = lane_ref.kv.len() == keep0
                && window.len() >= keep0
                && window[..keep0] == lane_ref.prefix[..];
            let mut keep = if inc { keep0 } else { 0 };
            if keep == window.len() {
                // fully cached: re-feed the last byte (identical row at an
                // identical position) so the round always scores >= 1
                keep -= 1;
            }
            let k_eff = k.min(s - window.len());
            let kept_blocks = blocks_for(keep, bl);
            let target = blocks_for(window.len() + k_eff, bl);
            // rollback credit: tail blocks beyond the kept prefix return
            // to the free list only where this lane holds the sole
            // reference (shared ones stay pinned by the prefix cache)
            let table = lane_ref.kv.block_table();
            avail += table[kept_blocks.min(table.len())..]
                .iter()
                .filter(|&&b| self.pool.blocks.refs(b) == 1)
                .count();
            need += target - kept_blocks;
            // first write after the rollback lands at `keep`: one clone
            // if that slot is still a shared block
            let cow_slot = keep / bl;
            need += usize::from(
                cow_slot < kept_blocks.min(table.len())
                    && self.pool.blocks.refs(table[cow_slot]) > 1,
            );
            windows.push(window);
            keeps.push(keep);
            k_effs.push(k_eff);
        }
        if need > avail {
            return Err(KvExhausted { needed: need, free: avail }.into());
        }

        // roll every lane back to its kept prefix (releases tail blocks;
        // keep == 0 is a full clear for re-prefill)
        {
            let KvPool { blocks, lanes } = &mut self.pool;
            for (ri, &(lane, _)) in reqs.iter().enumerate() {
                lanes[lane].kv.truncate_to(blocks, keeps[ri]);
                lanes[lane].prefix.truncate(keeps[ri]);
            }
        }

        // draft phase: the low-band cascade proposes k_eff bytes per lane
        let mut feeds: Vec<(usize, Vec<u8>, usize)> = Vec::with_capacity(reqs.len());
        let mut proposals: Vec<Vec<u8>> = Vec::with_capacity(reqs.len());
        {
            let NativeBackend { model, drafts, .. } = self;
            for (ri, &(lane, _)) in reqs.iter().enumerate() {
                let proposal = if k_effs[ri] > 0 {
                    drafts[lane].draft(model, windows[ri], k_effs[ri])
                } else {
                    Vec::new()
                };
                let mut bytes = windows[ri][keeps[ri]..].to_vec();
                bytes.extend_from_slice(&proposal);
                feeds.push((lane, bytes, k_effs[ri] + 1));
                proposals.push(proposal);
            }
        }

        // one multi-position verify sweep of the full packed model
        let tails = self.sweep_positions(&feeds)?;

        // an oversized sweep (fresh prompt, window slide, scoring clobber)
        // transiently needs one scratch arena per prefill position; only
        // the k + 1 verify positions per lane recur, so trim the pool back
        // to the steady state instead of pinning O(lanes * seq) arenas
        let steady: usize = k_effs.iter().map(|k| k + 2).sum();
        if self.spec_scratch.len() > steady {
            self.spec_scratch.truncate(steady);
        }

        // accept scan + rollback + commit
        let mut out = Vec::with_capacity(reqs.len());
        for (ri, &(lane, _)) in reqs.iter().enumerate() {
            let rows = &tails[ri];
            let proposal = &proposals[ri];
            let mut bytes = Vec::with_capacity(proposal.len() + 1);
            let mut accepted = 0usize;
            for (i, &draft) in proposal.iter().enumerate() {
                let target = greedy_token(&rows[i]) as u8;
                if draft == target {
                    bytes.push(draft);
                    accepted += 1;
                } else {
                    // rejection falls back to the verified token
                    bytes.push(target);
                    break;
                }
            }
            if accepted == proposal.len() {
                // every draft survived: the final row is a free extra token
                bytes.push(greedy_token(&rows[proposal.len()]) as u8);
            }
            {
                // drop the KV rows computed for rejected drafts, returning
                // their blocks to the free list
                let KvPool { blocks, lanes } = &mut self.pool;
                lanes[lane].kv.truncate_to(blocks, windows[ri].len() + accepted);
                let prefix = &mut lanes[lane].prefix;
                prefix.clear();
                prefix.extend_from_slice(windows[ri]);
                prefix.extend_from_slice(&proposal[..accepted]);
            }
            let dl = &mut self.drafts[lane];
            dl.rounds += 1;
            dl.drafted += proposal.len() as u64;
            dl.accepted += accepted as u64;
            out.push(SpecRound { bytes, drafted: proposal.len(), accepted });
        }
        Ok(out)
    }

    fn reset(&mut self) {
        self.pool.clear_all();
        for d in self.drafts.iter_mut() {
            d.clear();
        }
    }

    fn reset_lane(&mut self, lane: usize) {
        self.pool.reset_lane(lane);
        if let Some(d) = self.drafts.get_mut(lane) {
            d.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testing::micro_weights;
    use crate::model::{forward, nll_from_logits};

    fn tokens_for(window: &[u8], batch: usize) -> Vec<i32> {
        let mut t = Vec::with_capacity(batch * window.len());
        for _ in 0..batch {
            t.extend(window.iter().map(|&b| b as i32));
        }
        t
    }

    #[test]
    fn dense_engine_matches_reference_forward() {
        let w = micro_weights(21);
        let seq = w.config.seq_len;
        let window: Vec<u8> = (0..seq as u8).map(|i| i.wrapping_mul(37)).collect();
        let logits = forward(&w, &window, None);
        let want = nll_from_logits(&logits, &window);

        let pm = PackedModel::from_weights(&w, false).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let got = be.nll(&tokens_for(&window, 1)).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, r) in got.iter().zip(&want) {
            assert!((g - r).abs() < 1e-4, "{g} vs {r}");
        }
    }

    #[test]
    fn decode_step_is_incremental_and_consistent() {
        let w = micro_weights(22);
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let text = b"ab cd";
        let inc = be.decode_step(text).unwrap();
        // cache now holds the text; a fresh backend fed at once must agree
        let pm2 = PackedModel::from_weights(&w, true).unwrap();
        let mut fresh = NativeBackend::with_threads(pm2, 1, 1);
        let full = fresh.decode_step(text).unwrap();
        assert_eq!(inc, full);
        // extend by one byte: only the suffix is processed, same result as
        // a from-scratch forward over the longer text
        let longer = b"ab cde";
        let inc2 = be.decode_step(longer).unwrap();
        fresh.reset();
        let full2 = fresh.decode_step(longer).unwrap();
        assert_eq!(inc2, full2);
    }

    #[test]
    fn duplicate_batch_rows_reuse_results() {
        // padded eval batches repeat rows; the reuse path must return the
        // same values the recompute would
        let w = micro_weights(26);
        let window: Vec<u8> = (0..12u8).map(|i| i.wrapping_mul(19)).collect();
        let mut single =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        let one = single.nll(&tokens_for(&window, 1)).unwrap();
        let mut batched =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 2, 1);
        let two = batched.nll(&tokens_for(&window, 2)).unwrap();
        let per = window.len() - 1;
        assert_eq!(two.len(), 2 * per);
        assert_eq!(&two[..per], &one[..]);
        assert_eq!(&two[per..], &one[..]);
    }

    #[test]
    fn decode_step_empty_text_is_seeded() {
        let w = micro_weights(23);
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let row = be.decode_step(&[]).unwrap();
        assert_eq!(row.len(), 256);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_step_slides_past_seq_len() {
        let w = micro_weights(24);
        let seq = w.config.seq_len;
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        // text longer than the window: must not overflow the cache
        let text: Vec<u8> = (0..(seq as u8 + 5)).map(|i| i.wrapping_mul(13)).collect();
        let mut cur = text[..3].to_vec();
        while cur.len() < text.len() {
            let row = be.decode_step(&cur).unwrap();
            assert!(row.iter().all(|v| v.is_finite()));
            cur.push(text[cur.len()]);
        }
    }

    #[test]
    fn nll_rejects_bad_shapes_and_tokens() {
        let w = micro_weights(25);
        let pm = PackedModel::from_weights(&w, false).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        assert!(be.nll(&[0i32; 3]).is_err());
        let seq = be.seq();
        let mut toks = vec![0i32; seq];
        toks[2] = 999; // out of byte range
        assert!(be.nll(&toks).is_err());
    }

    #[test]
    fn set_lanes_reallocates_pool() {
        let w = micro_weights(27);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        assert_eq!(be.lanes(), 1);
        assert_eq!(be.set_lanes(3), 3);
        assert_eq!(be.lanes(), 3);
        assert_eq!(be.set_lanes(0), 1, "pool never drops below one lane");
    }

    #[test]
    fn decode_batch_rejects_bad_lane_sets() {
        let w = micro_weights(28);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        let t: &[u8] = b"ab";
        assert!(be.decode_batch(&[(2, t)]).is_err(), "out of range");
        assert!(be.decode_batch(&[(1, t), (0, t)]).is_err(), "unsorted");
        assert!(be.decode_batch(&[(0, t), (0, t)]).is_err(), "duplicate");
        // and a valid call still works afterwards
        assert_eq!(be.decode_batch(&[(0, t), (1, t)]).unwrap().len(), 2);
    }

    #[test]
    fn scoring_between_decode_steps_self_heals_lane0() {
        // serve interleaves nll scoring (which clobbers lane 0) with
        // generation; the next decode must re-prefill and match an
        // uninterrupted run exactly
        let w = micro_weights(30);
        let mk = || NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        let mut clean = mk();
        let a = clean.decode_step(b"ta ki").unwrap();
        let b = clean.decode_step(b"ta kiv").unwrap();

        let mut mixed = mk();
        let a2 = mixed.decode_step(b"ta ki").unwrap();
        let window: Vec<i32> = (0..mixed.seq() as i32).collect();
        mixed.nll(&window).unwrap(); // scoring call resets lane 0
        let b2 = mixed.decode_step(b"ta kiv").unwrap();
        assert_eq!(a, a2);
        assert_eq!(b, b2, "lane 0 did not recover from interleaved scoring");
    }

    #[test]
    fn set_kv_blocks_overrides_and_survives_lane_rebuilds() {
        let w = micro_weights(34);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        let st = be.kv_stats().unwrap();
        // worst-case default: every lane can hold a full window
        assert!(st.total_blocks * st.block_len >= be.seq());
        assert_eq!(st.free_blocks, st.total_blocks);
        let st = be.set_kv_blocks(Some(2), Some(4)).unwrap();
        assert_eq!((st.total_blocks, st.block_len), (2, 4));
        be.set_lanes(3);
        let st = be.kv_stats().unwrap();
        assert_eq!((st.total_blocks, st.block_len), (2, 4), "override lost on set_lanes");
        assert_eq!(st.lane_blocks, vec![0, 0, 0]);
    }

    #[test]
    fn decode_batch_exhaustion_is_typed_and_touches_no_lane() {
        let w = micro_weights(35);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        be.set_kv_blocks(Some(1), Some(4));
        // lane 0 takes the only block (3 positions)
        let a = be.decode_batch(&[(0, b"abc")]).unwrap().pop().unwrap();
        // lane 1 cannot start: typed exhaustion, before any state change
        let err = be.decode_batch(&[(1, b"xy")]).unwrap_err();
        assert!(err.downcast_ref::<KvExhausted>().is_some(), "untyped: {err}");
        // lane 0 is still incrementally consistent after the failed call
        let a2 = be.decode_batch(&[(0, b"abc")]).unwrap().pop().unwrap();
        assert_eq!(a, a2, "established lane perturbed by exhausted sweep");
        // growth past the block boundary exhausts too (4 -> 5 positions)
        be.decode_batch(&[(0, b"abcd")]).unwrap();
        let err = be.decode_batch(&[(0, b"abcde")]).unwrap_err();
        assert!(err.downcast_ref::<KvExhausted>().is_some(), "untyped: {err}");
        // eviction frees the arena: lane 1 can run now
        be.reset_lane(0);
        assert_eq!(be.decode_batch(&[(1, b"xy")]).unwrap().len(), 1);
    }

    #[test]
    fn paged_and_flat_configs_agree_bit_for_bit() {
        // block_len == seq (one block per lane) is exactly the old flat
        // layout; a fine-grained paging of the same model must match it
        let w = micro_weights(36);
        let seq = w.config.seq_len;
        let mk = |blocks: usize, bl: usize| {
            let mut be =
                NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
            be.set_kv_blocks(Some(blocks), Some(bl));
            be
        };
        let mut flat = mk(1, seq);
        let mut paged = mk(seq, 1); // one block per token
        let text: Vec<u8> = (0..seq as u8 + 3).map(|i| i.wrapping_mul(29)).collect();
        let mut cur = text[..2].to_vec();
        while cur.len() < text.len() {
            let a = flat.decode_step(&cur).unwrap();
            let b = paged.decode_step(&cur).unwrap();
            assert_eq!(a, b, "paged decode diverged at len {}", cur.len());
            cur.push(text[cur.len()]);
        }
    }

    #[test]
    fn spec_round_commits_greedy_tokens_and_keeps_prefix_consistent() {
        let w = micro_weights(37);
        let mk = || NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        // plain greedy reference
        let mut plain = mk();
        let mut want = b"ta ".to_vec();
        for _ in 0..6 {
            let row = plain.decode_batch(&[(0, &want)]).unwrap().pop().unwrap();
            want.push(crate::engine::greedy_token(&row) as u8);
        }
        // speculative: same bytes, fewer rounds
        let mut spec = mk();
        let mut got = b"ta ".to_vec();
        let mut rounds = 0usize;
        while got.len() < want.len() {
            let r = spec
                .decode_batch_spec(&[(0, &got)], 2)
                .unwrap()
                .pop()
                .unwrap();
            assert!(!r.bytes.is_empty(), "a round must commit at least one byte");
            assert!(r.bytes.len() <= r.drafted + 1);
            assert!(r.accepted <= r.drafted);
            for &b in r.bytes.iter().take(want.len() - got.len()) {
                got.push(b);
            }
            rounds += 1;
            assert!(rounds <= 6, "speculation never terminated");
        }
        assert_eq!(got, want, "speculative greedy diverged from plain");
        // lane prefix/kv invariant holds for the next (plain) call
        let row = spec.decode_batch(&[(0, &got)]).unwrap().pop().unwrap();
        let row2 = plain.decode_batch(&[(0, &want)]).unwrap().pop().unwrap();
        assert_eq!(row, row2, "post-spec lane state inconsistent");
        let st = spec.spec_stats().unwrap();
        assert!(st.rounds >= 1 && st.drafted >= 1);
        assert_eq!(st.lane_drafted.len(), 1);
    }

    #[test]
    fn spec_exhaustion_is_typed_and_rollback_releases_blocks() {
        let w = micro_weights(38);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        be.set_kv_blocks(Some(1), Some(4));
        // 2-byte prompt + k=4 drafts needs 2 blocks; only 1 exists
        let err = be.decode_batch_spec(&[(0, b"ab")], 4).unwrap_err();
        assert!(err.downcast_ref::<KvExhausted>().is_some(), "untyped: {err}");
        let st = be.kv_stats().unwrap();
        assert_eq!(st.free_blocks, st.total_blocks, "failed plan touched lane state");
        // k clamped to the free window fits: 2-byte prompt + k<=1 draft
        let r = be.decode_batch_spec(&[(0, b"ab")], 1).unwrap().pop().unwrap();
        assert!(!r.bytes.is_empty());
        // whatever was rejected has been rolled back: held blocks cover
        // exactly the verified prefix
        let st = be.kv_stats().unwrap();
        let held: usize = st.lane_blocks.iter().sum();
        let verified = 2 + r.accepted;
        assert_eq!(held, blocks_for(verified, st.block_len));
    }

    #[test]
    fn set_spec_reports_effective_config() {
        use crate::engine::SpecConfig;
        let w = micro_weights(39);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        let eff = be.set_spec(SpecConfig { k: 4, enabled: true });
        assert!(eff.enabled && eff.k == 4);
        let eff = be.set_spec(SpecConfig { k: 0, enabled: true });
        assert!(!eff.enabled, "k = 0 cannot be enabled");
        let st = be.spec_stats().unwrap();
        assert_eq!((st.rounds, st.drafted, st.accepted), (0, 0, 0));
    }

    #[test]
    fn sweep_counter_amortizes_over_lanes() {
        let w = micro_weights(40);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        assert_eq!(be.sweeps(), 0);
        // two lanes prefilling 3-byte prompts in lock step: 3 sweeps, not
        // 6 — the amortization the counter exists to expose
        be.decode_batch(&[(0, b"abc"), (1, b"xyz")]).unwrap();
        assert_eq!(be.sweeps(), 3);
        // a speculative round is one verify sweep regardless of k
        let before = be.sweeps();
        be.decode_batch_spec(&[(0, b"abcd")], 2).unwrap();
        assert_eq!(be.sweeps(), before + 1);
    }

    #[test]
    fn retain_adopt_roundtrip_shares_blocks_and_matches_prefill() {
        let w = micro_weights(41);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        be.set_kv_blocks(Some(8), Some(4));
        let prompt: &[u8] = b"ta kiv";
        be.decode_batch(&[(0, prompt)]).unwrap();
        let cached = be.kv_retain_prefix(0, prompt.len()).unwrap();
        assert_eq!(cached.len(), blocks_for(prompt.len(), 4));
        assert_eq!(be.kv_stats().unwrap().shared_blocks, cached.len());
        // evicting the donor lane keeps the cached blocks alive
        be.reset_lane(0);
        let st = be.kv_stats().unwrap();
        assert_eq!(st.total_blocks - st.free_blocks, cached.len());
        assert_eq!(st.shared_blocks, 0, "cache now holds the only reference");
        // adopt into lane 1: decode runs incrementally (one sweep for the
        // one unseen byte) and matches an independent prefill exactly
        assert!(be.kv_adopt_prefix(1, &cached, prompt.len(), prompt));
        let sweeps0 = be.sweeps();
        let longer: &[u8] = b"ta kivo";
        let got = be.decode_batch(&[(1, longer)]).unwrap().pop().unwrap();
        assert_eq!(be.sweeps() - sweeps0, 1, "adopted lane re-prefilled");
        let mut fresh =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        fresh.decode_step(prompt).unwrap();
        let want = fresh.decode_step(longer).unwrap();
        assert_eq!(got, want, "shared-prefix decode diverged");
        // dropping the cache refs and the lane returns every block
        be.kv_release_blocks(&cached);
        be.reset_lane(1);
        let st = be.kv_stats().unwrap();
        assert_eq!(st.free_blocks, st.total_blocks, "blocks leaked");
    }

    #[test]
    fn kv_adopt_rejects_malformed_mappings() {
        let w = micro_weights(42);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        be.set_kv_blocks(Some(4), Some(4));
        be.decode_batch(&[(0, b"abcde")]).unwrap();
        let cached = be.kv_retain_prefix(0, 5).unwrap();
        assert!(!be.kv_adopt_prefix(9, &cached, 5, b"abcde"), "lane out of range");
        assert!(!be.kv_adopt_prefix(1, &cached, 5, b"abcd"), "prefix/positions mismatch");
        assert!(!be.kv_adopt_prefix(1, &cached[..1], 5, b"abcde"), "too few blocks");
        assert!(!be.kv_adopt_prefix(1, &cached, 0, b""), "empty adoption");
        assert!(be.kv_adopt_prefix(1, &cached, 5, b"abcde"));
        be.kv_release_blocks(&cached);
    }

    #[test]
    fn decode_batch_matches_decode_step_per_lane() {
        // same prompts through (a) two independent single-lane backends and
        // (b) one two-lane backend — logits must be bit-identical
        let w = micro_weights(29);
        let texts: [&[u8]; 2] = [b"ta ki", b"vo"];
        let mut want = Vec::new();
        for t in texts {
            let mut be =
                NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
            want.push(be.decode_step(t).unwrap());
        }
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        let got = be.decode_batch(&[(0, texts[0]), (1, texts[1])]).unwrap();
        assert_eq!(got, want);
    }
}
