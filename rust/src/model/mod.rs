//! Model substrate: artifact metadata, weight storage, and a pure-Rust
//! forward pass that replicates `python/compile/model.py` op-for-op in f32.
//!
//! The Rust forward exists for *calibration*: it exposes every linear
//! layer's input activations (which the PJRT path cannot), from which
//! `calib` accumulates the GPTQ Hessians. An integration test checks its
//! logits against the AOT HLO module.

use crate::tensor::Matrix;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub const RMS_EPS: f32 = 1e-5;
const GELU_C: f32 = 0.797_884_56;

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub param_order: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        let g = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing config field {k}"))
        };
        let param_order: Vec<String> = j
            .get("param_order")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing param_order"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let mut param_shapes = BTreeMap::new();
        let shapes = j
            .get("param_shapes")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing param_shapes"))?;
        for (k, v) in shapes {
            let dims: Vec<usize> = v
                .as_arr()
                .ok_or_else(|| anyhow!("bad shape for {k}"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            param_shapes.insert(k.clone(), dims);
        }
        Ok(ModelConfig {
            name: j.get("name").and_then(Json::as_str).unwrap_or("model").to_string(),
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            d_ff: g("d_ff")?,
            seq_len: g("seq_len")?,
            vocab: g("vocab")?,
            param_order,
            param_shapes,
        })
    }

    /// Names of the quantized linear layers (paper: transformer-block
    /// projections; embeddings, norms and the LM head stay fp16).
    pub fn linear_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for i in 0..self.n_layers {
            for k in ["wq", "wk", "wv", "wo", "w1", "w2"] {
                out.push(format!("l{i}.{k}"));
            }
        }
        out
    }
}

/// A parameter tensor: 1-D (norm gains) or 2-D.
#[derive(Clone, Debug)]
pub enum Tensor {
    Vec1(Vec<f32>),
    Mat(Matrix),
}

impl Tensor {
    pub fn as_mat(&self) -> &Matrix {
        match self {
            Tensor::Mat(m) => m,
            Tensor::Vec1(_) => panic!("expected matrix tensor"),
        }
    }

    pub fn as_vec(&self) -> &[f32] {
        match self {
            Tensor::Vec1(v) => v,
            Tensor::Mat(_) => panic!("expected vector tensor"),
        }
    }

    pub fn elements(&self) -> usize {
        match self {
            Tensor::Vec1(v) => v.len(),
            Tensor::Mat(m) => m.data.len(),
        }
    }
}

/// All model weights, keyed by canonical parameter name. Matrices are in
/// MODEL orientation `[in, out]` (the forward computes x @ W).
pub struct Weights {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    /// Load from `model_<cfg>.json` + the raw f32-LE binary beside it.
    pub fn load(meta_path: &Path) -> Result<Weights> {
        let meta_src = std::fs::read_to_string(meta_path)
            .with_context(|| format!("reading {meta_path:?}"))?;
        let meta = Json::parse(&meta_src).map_err(|e| anyhow!("bad meta json: {e}"))?;
        let config = ModelConfig::from_json(
            meta.get("config").ok_or_else(|| anyhow!("missing config"))?,
        )?;
        let bin_path = meta_path.with_extension("bin");
        let raw = std::fs::read(&bin_path).with_context(|| format!("reading {bin_path:?}"))?;
        if raw.len() % 4 != 0 {
            bail!("weight binary not a multiple of 4 bytes");
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let tensors_meta = meta
            .get("tensors")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing tensors"))?;
        let mut tensors = BTreeMap::new();
        for (name, tm) in tensors_meta {
            let off = tm.get("offset").and_then(Json::as_usize).ok_or_else(|| anyhow!("offset"))?;
            let shape: Vec<usize> = tm
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let count: usize = shape.iter().product();
            if off + count > floats.len() {
                bail!("tensor {name} out of range");
            }
            let data = floats[off..off + count].to_vec();
            let t = match shape.len() {
                1 => Tensor::Vec1(data),
                2 => Tensor::Mat(Matrix::from_vec(shape[0], shape[1], data)),
                d => bail!("unsupported rank {d} for {name}"),
            };
            tensors.insert(name.clone(), t);
        }
        for name in &config.param_order {
            if !tensors.contains_key(name) {
                bail!("missing tensor {name}");
            }
        }
        Ok(Weights { config, tensors })
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.tensors[name]
    }

    pub fn set_matrix(&mut self, name: &str, m: Matrix) {
        self.tensors.insert(name.to_string(), Tensor::Mat(m));
    }

    /// Flatten in canonical order (the HLO positional argument list).
    pub fn flat_in_order(&self) -> Vec<&Tensor> {
        self.config.param_order.iter().map(|n| &self.tensors[n]).collect()
    }

    pub fn total_elements(&self) -> usize {
        self.tensors.values().map(Tensor::elements).sum()
    }
}

pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let d = x.len();
    let ms: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
    let inv = 1.0 / ((ms as f32) + RMS_EPS).sqrt();
    for j in 0..d {
        out[j] = x[j] * inv * g[j];
    }
}

pub fn gelu_tanh(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + 0.044715 * x * x * x)).tanh())
}

/// Captured per-linear inputs for one forward call (calibration hook).
#[derive(Default)]
pub struct Capture {
    /// rows = tokens, keyed by linear name; wq/wk/wv share "l{i}.attn_in"
    pub activations: BTreeMap<String, Matrix>,
}

/// Pure-Rust forward: tokens (one sequence) -> logits [seq, vocab].
/// When `capture` is provided, every linear's input activations are stored.
pub fn forward(
    w: &Weights,
    tokens: &[u8],
    mut capture: Option<&mut Capture>,
) -> Matrix {
    let cfg = &w.config;
    let (s, d) = (tokens.len(), cfg.d_model);
    assert!(s <= cfg.seq_len, "sequence too long");
    let tok_emb = w.get("tok_emb").as_mat();
    let pos_emb = w.get("pos_emb").as_mat();
    // x: [s, d]
    let mut x = Matrix::zeros(s, d);
    for (t, &b) in tokens.iter().enumerate() {
        for j in 0..d {
            x.set(t, j, tok_emb.get(b as usize, j) + pos_emb.get(t, j));
        }
    }
    let heads = cfg.n_heads;
    let dh = cfg.d_head();
    let scale = 1.0 / (dh as f32).sqrt();

    for layer in 0..cfg.n_layers {
        let p = |k: &str| format!("l{layer}.{k}");
        // --- attention ---
        let ln1 = w.get(&p("ln1")).as_vec();
        let mut h = Matrix::zeros(s, d);
        for t in 0..s {
            let (src, dst) = (x.row(t).to_vec(), h.row_mut(t));
            rmsnorm(&src, ln1, dst);
        }
        if let Some(cap) = capture.as_deref_mut() {
            cap.activations.insert(p("attn_in"), h.clone());
        }
        let q = h.matmul(w.get(&p("wq")).as_mat());
        let k = h.matmul(w.get(&p("wk")).as_mat());
        let v = h.matmul(w.get(&p("wv")).as_mat());
        // causal attention per head
        let mut attn_out = Matrix::zeros(s, d);
        let mut probs = vec![0f32; s];
        for hd in 0..heads {
            let c0 = hd * dh;
            for t in 0..s {
                // logits over 0..=t
                let mut maxv = f32::NEG_INFINITY;
                for u in 0..=t {
                    let mut dot = 0f32;
                    for j in 0..dh {
                        dot += q.get(t, c0 + j) * k.get(u, c0 + j);
                    }
                    let l = dot * scale;
                    probs[u] = l;
                    maxv = maxv.max(l);
                }
                let mut z = 0f32;
                for u in 0..=t {
                    probs[u] = (probs[u] - maxv).exp();
                    z += probs[u];
                }
                let inv_z = 1.0 / z;
                for j in 0..dh {
                    let mut acc = 0f32;
                    for u in 0..=t {
                        acc += probs[u] * inv_z * v.get(u, c0 + j);
                    }
                    attn_out.set(t, c0 + j, acc);
                }
            }
        }
        if let Some(cap) = capture.as_deref_mut() {
            cap.activations.insert(p("wo_in"), attn_out.clone());
        }
        let proj = attn_out.matmul(w.get(&p("wo")).as_mat());
        x.add_scaled(&proj, 1.0);

        // --- MLP ---
        let ln2 = w.get(&p("ln2")).as_vec();
        let mut h2 = Matrix::zeros(s, d);
        for t in 0..s {
            let (src, dst) = (x.row(t).to_vec(), h2.row_mut(t));
            rmsnorm(&src, ln2, dst);
        }
        if let Some(cap) = capture.as_deref_mut() {
            cap.activations.insert(p("w1_in"), h2.clone());
        }
        let mut ff = h2.matmul(w.get(&p("w1")).as_mat());
        for vv in ff.data.iter_mut() {
            *vv = gelu_tanh(*vv);
        }
        if let Some(cap) = capture.as_deref_mut() {
            cap.activations.insert(p("w2_in"), ff.clone());
        }
        let down = ff.matmul(w.get(&p("w2")).as_mat());
        x.add_scaled(&down, 1.0);
    }

    // final norm + unembed
    let lnf = w.get("ln_f").as_vec();
    let mut xf = Matrix::zeros(s, d);
    for t in 0..s {
        let (src, dst) = (x.row(t).to_vec(), xf.row_mut(t));
        rmsnorm(&src, lnf, dst);
    }
    xf.matmul(w.get("unemb").as_mat())
}

/// Per-position next-token NLL from logits (matches model.py `nll`).
pub fn nll_from_logits(logits: &Matrix, tokens: &[u8]) -> Vec<f32> {
    let s = tokens.len();
    let mut out = Vec::with_capacity(s - 1);
    for t in 0..s - 1 {
        let row = logits.row(t);
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let logz: f32 = maxv + row.iter().map(|&v| (v - maxv).exp()).sum::<f32>().ln();
        out.push(logz - row[tokens[t + 1] as usize]);
    }
    out
}

/// Which shared-input group a linear layer belongs to (wq/wk/wv share the
/// rmsnorm output, so they share one Hessian).
pub fn activation_key(linear_name: &str) -> String {
    let (layer, kind) = linear_name.split_once('.').expect("l{i}.{kind}");
    match kind {
        "wq" | "wk" | "wv" => format!("{layer}.attn_in"),
        "wo" => format!("{layer}.wo_in"),
        "w1" => format!("{layer}.w1_in"),
        "w2" => format!("{layer}.w2_in"),
        other => panic!("unknown linear {other}"),
    }
}

/// Synthetic model builders shared by unit tests, integration tests and
/// the decode benchmarks (compiled unconditionally — integration tests and
/// `benches/` link the library without `cfg(test)`).
pub mod testing {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Hand-build a micro model (no artifact dependency).
    pub fn micro_weights(seed: u64) -> Weights {
        let mut w = synth_weights(seed, 16, 2, 2, 32, 12);
        w.config.name = "micro".into();
        w
    }

    /// Hand-build a synthetic model of the given shape (no artifact
    /// dependency): unit norm gains, N(0, 1/√fan-in) linears, byte vocab.
    /// The serve-throughput bench uses a larger shape than `micro_weights`
    /// so the per-token GEMV cost is measurable.
    pub fn synth_weights(
        seed: u64,
        d: usize,
        layers: usize,
        heads: usize,
        dff: usize,
        seq: usize,
    ) -> Weights {
        let vocab = 256usize;
        let mut order = vec!["tok_emb".to_string(), "pos_emb".to_string()];
        for i in 0..layers {
            for k in ["ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2"] {
                order.push(format!("l{i}.{k}"));
            }
        }
        order.push("ln_f".into());
        order.push("unemb".into());
        let mut shapes = BTreeMap::new();
        let mut rng = Pcg32::seeded(seed);
        let mut tensors = BTreeMap::new();
        for name in &order {
            let base = name.split('.').last().unwrap();
            let shape: Vec<usize> = match base {
                "tok_emb" => vec![vocab, d],
                "pos_emb" => vec![seq, d],
                "unemb" => vec![d, vocab],
                "ln1" | "ln2" | "ln_f" => vec![d],
                "wq" | "wk" | "wv" | "wo" => vec![d, d],
                "w1" => vec![d, dff],
                "w2" => vec![dff, d],
                _ => unreachable!(),
            };
            shapes.insert(name.clone(), shape.clone());
            let count: usize = shape.iter().product();
            let t = if shape.len() == 1 {
                Tensor::Vec1(vec![1.0; count])
            } else {
                let std = 1.0 / (shape[0] as f32).sqrt();
                Tensor::Mat(Matrix::from_vec(
                    shape[0],
                    shape[1],
                    (0..count).map(|_| rng.normal_f32() * std).collect(),
                ))
            };
            tensors.insert(name.clone(), t);
        }
        Weights {
            config: ModelConfig {
                name: format!("synth-d{d}-l{layers}"),
                d_model: d,
                n_layers: layers,
                n_heads: heads,
                d_ff: dff,
                seq_len: seq,
                vocab,
                param_order: order,
                param_shapes: shapes,
            },
            tensors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testing::micro_weights;
    use super::*;

    #[test]
    fn forward_shapes_and_finite() {
        let w = micro_weights(1);
        let tokens: Vec<u8> = (0..12).map(|i| (i * 17) as u8).collect();
        let logits = forward(&w, &tokens, None);
        assert_eq!((logits.rows, logits.cols), (12, 256));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_is_causal() {
        let w = micro_weights(2);
        let mut tokens: Vec<u8> = (0..12).map(|i| (i * 31) as u8).collect();
        let a = forward(&w, &tokens, None);
        tokens[8] = tokens[8].wrapping_add(1);
        let b = forward(&w, &tokens, None);
        for t in 0..8 {
            for j in 0..256 {
                assert!((a.get(t, j) - b.get(t, j)).abs() < 1e-6, "leak at t={t}");
            }
        }
        assert!((0..256).any(|j| (a.get(8, j) - b.get(8, j)).abs() > 1e-6));
    }

    #[test]
    fn nll_matches_manual_softmax() {
        let w = micro_weights(3);
        let tokens: Vec<u8> = vec![10, 20, 30, 40];
        let logits = forward(&w, &tokens, None);
        let nll = nll_from_logits(&logits, &tokens);
        assert_eq!(nll.len(), 3);
        // manual check at position 0
        let row = logits.row(0);
        let z: f64 = row.iter().map(|&v| (v as f64).exp()).sum();
        let want = z.ln() - row[20] as f64;
        assert!((nll[0] as f64 - want).abs() < 1e-4);
        assert!(nll.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn capture_collects_all_linear_inputs() {
        let w = micro_weights(4);
        let tokens: Vec<u8> = (0..12u8).collect();
        let mut cap = Capture::default();
        forward(&w, &tokens, Some(&mut cap));
        for name in w.config.linear_names() {
            let key = activation_key(&name);
            let act = cap.activations.get(&key).expect(&key);
            assert_eq!(act.rows, 12);
            let want_cols = match name.split('.').last().unwrap() {
                "w2" => w.config.d_ff,
                _ => w.config.d_model,
            };
            assert_eq!(act.cols, want_cols, "{name}");
        }
    }

    #[test]
    fn activation_key_mapping() {
        assert_eq!(activation_key("l0.wq"), "l0.attn_in");
        assert_eq!(activation_key("l3.w2"), "l3.w2_in");
    }
}
