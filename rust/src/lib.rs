//! HBLLM — wavelet-enhanced high-fidelity 1-bit post-training quantization
//! for LLMs (NeurIPS 2025) — full-system Rust + JAX + Pallas reproduction.
//!
//! Start with `README.md` at the repository root (quickstart, backend
//! matrix), then `docs/ARCHITECTURE.md` (module graph + request
//! lifecycle), `docs/API.md` (the serving wire protocols — TCP verbs and
//! HTTP/SSE endpoints), and `docs/FORMAT.md` (the packed `.hbq` wire
//! format).
//!
//! Layer map:
//! * [`quant`] — the paper's contribution: HaarQuant + structure-aware
//!   grouping, and every baseline (BiLLM, ARB-LLM, PB-LLM, FrameQuant).
//! * [`haar`], [`tensor`], [`pack`] — numeric substrates.
//! * [`model`], [`calib`], [`data`], [`eval`] — the PTQ evaluation stack
//!   (byte-level GPT, Hessian collection, perplexity + zero-shot QA).
//! * [`runtime`] — PJRT loader/executor for the AOT HLO artifacts.
//! * [`engine`] — native packed-weight inference: the byte-level
//!   transformer executed directly from Haar-packed 1-bit linears, with a
//!   KV-lane pool for multi-sequence decoding and the [`engine::Backend`]
//!   trait that makes eval/serving backend-generic
//!   (`--backend {xla,native}`).
//! * [`coordinator`] — quantization job scheduling, scoring batches, and
//!   the continuous-batching generation server with its TCP and HTTP/SSE
//!   front-ends and two-tier request priorities.

pub mod calib;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod haar;
pub mod model;
pub mod pack;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
