//! Evaluation harness: perplexity over the synthetic corpora and zero-shot
//! accuracy over the 9 QA task families (lm-eval-harness-style option
//! scoring). Backend-generic: everything scores through
//! [`engine::Backend::nll`](crate::engine::Backend::nll), so the XLA runners and the native packed
//! engine are interchangeable here.

use crate::data::{batches, Corpus, TaskFile, TaskItem};
use crate::engine::Backend;
use anyhow::Result;

/// Perplexity = exp(mean per-token NLL) over non-overlapping windows.
pub fn perplexity(be: &mut dyn Backend, corpus: &Corpus, max_windows: usize) -> Result<f64> {
    let (batch, seq) = (be.batch(), be.seq());
    let wins = corpus.windows(seq, max_windows);
    anyhow::ensure!(!wins.is_empty(), "corpus {} too small", corpus.name);
    let mut total = 0f64;
    let mut count = 0usize;
    for batch_item in batches(&wins, batch, seq) {
        let nll = be.nll(&batch_item.tokens)?;
        let per_row = seq - 1;
        for r in 0..batch_item.valid {
            for v in &nll[r * per_row..(r + 1) * per_row] {
                total += *v as f64;
            }
            count += per_row;
        }
    }
    Ok((total / count as f64).exp())
}

/// Score one QA item: per option, the summed NLL of the option tokens given
/// the prompt. Returns the argmin option index.
fn option_scores(be: &mut dyn Backend, item: &TaskItem) -> Result<Vec<f64>> {
    let (batch, seq) = (be.batch(), be.seq());
    // Build one sequence per option: prompt + option, left-truncated to seq.
    let mut rows: Vec<(Vec<u8>, usize, usize)> = Vec::new(); // (tokens, opt_start, opt_end)
    for opt in &item.options {
        let mut text = item.prompt.clone().into_bytes();
        let opt_b = opt.as_bytes();
        let prompt_len = text.len();
        text.extend_from_slice(opt_b);
        // left-truncate keeping the whole option
        let (tokens, opt_start) = if text.len() > seq {
            let cut = text.len() - seq;
            (text[cut..].to_vec(), prompt_len.saturating_sub(cut))
        } else {
            (text, prompt_len)
        };
        let opt_end = tokens.len();
        rows.push((tokens, opt_start, opt_end));
    }
    // batch the option sequences (pad to full batch)
    let mut scores = vec![0f64; rows.len()];
    for chunk_start in (0..rows.len()).step_by(batch) {
        let chunk = &rows[chunk_start..(chunk_start + batch).min(rows.len())];
        let mut tokens = vec![b'\n' as i32; batch * seq];
        for (r, (row, _, _)) in chunk.iter().enumerate() {
            for (c, &b) in row.iter().enumerate() {
                tokens[r * seq + c] = b as i32;
            }
        }
        for r in chunk.len()..batch {
            let (src, dst) = tokens.split_at_mut(r * seq);
            dst[..seq].copy_from_slice(&src[(chunk.len() - 1) * seq..chunk.len() * seq]);
        }
        let nll = be.nll(&tokens)?;
        let per_row = seq - 1;
        for (r, (_, opt_start, opt_end)) in chunk.iter().enumerate() {
            // NLL at position t predicts token t+1; option tokens occupy
            // [opt_start, opt_end), so sum NLL[t] for t in [opt_start-1, opt_end-1).
            // Length-normalized (acc_norm-style): options differ in byte
            // length across families, and raw sums favor short options.
            let lo = opt_start.saturating_sub(1);
            let hi = (opt_end - 1).min(per_row);
            let mut s = 0f64;
            for t in lo..hi {
                s += nll[r * per_row + t] as f64;
            }
            scores[chunk_start + r] = s / (hi - lo).max(1) as f64;
        }
    }
    Ok(scores)
}

/// Accuracy over one task family.
pub fn task_accuracy(be: &mut dyn Backend, task: &TaskFile, max_items: usize) -> Result<f64> {
    let items = &task.items[..task.items.len().min(max_items)];
    anyhow::ensure!(!items.is_empty(), "empty task {}", task.family);
    let mut correct = 0usize;
    for item in items {
        let scores = option_scores(be, item)?;
        let pred = scores
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == item.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len() as f64)
}

/// Mean accuracy across task families (the AvgQA column).
pub fn avg_qa(be: &mut dyn Backend, tasks: &[TaskFile], max_items: usize) -> Result<f64> {
    let mut acc = 0f64;
    for t in tasks {
        acc += task_accuracy(be, t, max_items)?;
    }
    Ok(acc / tasks.len() as f64)
}

#[cfg(test)]
mod tests {
    // PJRT-dependent paths are exercised by rust/tests/integration.rs (they
    // need artifacts/); the native-backend path by rust/tests/engine_parity.rs.
    use crate::data::TaskItem;
    use crate::engine::{Backend, NativeBackend, PackedModel};
    use crate::model::testing::micro_weights;

    #[test]
    fn option_window_arithmetic() {
        // verify the left-truncation logic used in option_scores
        let seq = 16usize;
        let prompt = "x".repeat(20);
        let item = TaskItem { prompt, options: vec!["abcd".into()], correct: 0 };
        let mut text = item.prompt.clone().into_bytes();
        let prompt_len = text.len();
        text.extend_from_slice(item.options[0].as_bytes());
        let cut = text.len() - seq;
        let opt_start = prompt_len.saturating_sub(cut);
        assert_eq!(text.len() - cut, seq);
        assert_eq!(opt_start, 12); // 4 option bytes at the end of 16
    }

    #[test]
    fn perplexity_over_native_backend() {
        let w = micro_weights(41);
        let seq = w.config.seq_len;
        let corpus = crate::data::Corpus {
            name: "synthetic".into(),
            data: (0..seq * 6).map(|i| (i % 97) as u8 + 32).collect(),
        };
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, false).unwrap(), 2, 1);
        let p = super::perplexity(&mut be, &corpus, 4).unwrap();
        assert!(p.is_finite() && p > 1.0, "ppl {p}");
        assert_eq!(be.batch(), 2);
    }
}
