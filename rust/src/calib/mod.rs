//! Calibration: run the pure-Rust forward over calibration windows and
//! accumulate the GPTQ Hessian H = 2 Σ x xᵀ per shared-input group
//! (wq/wk/wv share one Hessian; wo, w1, w2 get their own).

use crate::model::{activation_key, forward, Capture, Weights};
use crate::quant::{HessianCtx, DEFAULT_LAMBDA};
use crate::tensor::linalg::Sq;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Accumulated Hessians keyed by activation-group name (e.g. "l0.attn_in").
pub struct Calibration {
    pub hessians: BTreeMap<String, Sq>,
    pub samples: usize,
}

/// Run calibration over `windows` (each a byte sequence ≤ seq_len).
pub fn collect(w: &Weights, windows: &[&[u8]]) -> Calibration {
    let mut hessians: BTreeMap<String, Sq> = BTreeMap::new();
    let mut samples = 0usize;
    for win in windows {
        let mut cap = Capture::default();
        forward(w, win, Some(&mut cap));
        samples += win.len();
        for (key, act) in cap.activations {
            let d = act.cols;
            let h = hessians.entry(key).or_insert_with(|| Sq::zeros(d));
            // H += 2 Σ_t x_t x_tᵀ
            for t in 0..act.rows {
                let row = act.row(t);
                for a in 0..d {
                    let xa = 2.0 * row[a] as f64;
                    if xa == 0.0 {
                        continue;
                    }
                    let hrow = &mut h.data[a * d..(a + 1) * d];
                    for (b, &xb) in row.iter().enumerate() {
                        hrow[b] += xa * xb as f64;
                    }
                }
            }
        }
    }
    Calibration { hessians, samples }
}

impl Calibration {
    /// Hessian context for one linear layer (by canonical linear name).
    pub fn ctx_for(&self, linear_name: &str) -> Result<HessianCtx> {
        let key = activation_key(linear_name);
        let h = self
            .hessians
            .get(&key)
            .ok_or_else(|| anyhow::anyhow!("no hessian for {key}"))?;
        HessianCtx::new(h.clone(), DEFAULT_LAMBDA).map_err(|e| anyhow::anyhow!(e))
    }

    /// Factor every Hessian once (Cholesky of the damped inverse is O(d³) —
    /// sharing across methods and across wq/wk/wv matters).
    pub fn contexts(&self) -> Result<CtxMap> {
        let mut map = BTreeMap::new();
        for (key, h) in &self.hessians {
            let ctx = HessianCtx::new(h.clone(), DEFAULT_LAMBDA)
                .map_err(|e| anyhow::anyhow!("{key}: {e}"))?;
            map.insert(key.clone(), Arc::new(ctx));
        }
        Ok(CtxMap { map })
    }
}

/// Pre-factored Hessian contexts keyed by activation group.
#[derive(Clone)]
pub struct CtxMap {
    map: BTreeMap<String, Arc<HessianCtx>>,
}

impl CtxMap {
    pub fn for_linear(&self, linear_name: &str) -> Result<Arc<HessianCtx>> {
        let key = activation_key(linear_name);
        self.map
            .get(&key)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no hessian context for {key}"))
    }

    /// Build a CtxMap with identity Hessians (no-calibration mode).
    pub fn identity_for(weights: &crate::model::Weights) -> CtxMap {
        let mut map = BTreeMap::new();
        for name in weights.config.linear_names() {
            let key = activation_key(&name);
            if !map.contains_key(&key) {
                let d = weights.get(&name).as_mat().rows; // [in, out]: in = rows
                map.insert(key, Arc::new(HessianCtx::identity(d)));
            }
        }
        CtxMap { map }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testing::micro_weights;

    #[test]
    fn collects_one_hessian_per_group() {
        let w = micro_weights(7);
        let win: Vec<u8> = (0..12u8).map(|i| i * 3).collect();
        let calib = collect(&w, &[&win]);
        // 2 layers × 4 groups
        assert_eq!(calib.hessians.len(), 8);
        let h = &calib.hessians["l0.attn_in"];
        assert_eq!(h.n, 16);
        // symmetric PSD-ish: diag positive, symmetric
        for i in 0..h.n {
            assert!(h.get(i, i) > 0.0);
            for j in 0..h.n {
                assert!((h.get(i, j) - h.get(j, i)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn ctx_factors() {
        let w = micro_weights(8);
        let win: Vec<u8> = (0..12u8).collect();
        let calib = collect(&w, &[&win, &win]);
        for name in ["l0.wq", "l0.wo", "l1.w1", "l1.w2"] {
            let ctx = calib.ctx_for(name).unwrap();
            assert!(ctx.hinv_diag.iter().all(|&d| d > 0.0), "{name}");
        }
    }

    #[test]
    fn more_windows_more_mass() {
        let w = micro_weights(9);
        let win: Vec<u8> = (5..12u8).collect();
        let c1 = collect(&w, &[&win]);
        let c2 = collect(&w, &[&win, &win]);
        let t1 = c1.hessians["l0.attn_in"].get(0, 0);
        let t2 = c2.hessians["l0.attn_in"].get(0, 0);
        assert!((t2 - 2.0 * t1).abs() < 1e-6 * t1.abs().max(1.0));
    }
}
