//! 1-level (optionally multi-level) 1D Haar transform, matching the paper's
//! §3.6 convention and the L1 Pallas kernel bit-for-bit:
//!
//!   `analysis : lo[k] = (x[2k] + x[2k+1]) / 2,  hi[k] = (x[2k] - x[2k+1]) / 2`
//!   `synthesis: x[2k] = lo[k] + hi[k],          x[2k+1] = lo[k] - hi[k]`
//!
//! Output layout is `[low band ++ high band]` along the transformed axis.
//! The pair is biorthogonal and exactly invertible; cost is O(d) per row
//! (the "local convolution" the paper contrasts with FrameQuant's O(d²)).

use crate::tensor::Matrix;

/// In-place-style analysis of one row slice into a fresh Vec.
pub fn fwd_1d(x: &[f32]) -> Vec<f32> {
    assert!(x.len() % 2 == 0, "haar needs even length, got {}", x.len());
    let h = x.len() / 2;
    let mut out = vec![0.0f32; x.len()];
    for k in 0..h {
        out[k] = (x[2 * k] + x[2 * k + 1]) * 0.5;
        out[h + k] = (x[2 * k] - x[2 * k + 1]) * 0.5;
    }
    out
}

pub fn inv_1d(c: &[f32]) -> Vec<f32> {
    assert!(c.len() % 2 == 0);
    let h = c.len() / 2;
    let mut out = vec![0.0f32; c.len()];
    for k in 0..h {
        out[2 * k] = c[k] + c[h + k];
        out[2 * k + 1] = c[k] - c[h + k];
    }
    out
}

/// Row-wise analysis: every row of W transformed independently.
pub fn fwd_rows(w: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        out.row_mut(i).copy_from_slice(&fwd_1d(w.row(i)));
    }
    out
}

pub fn inv_rows(c: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(c.rows, c.cols);
    for i in 0..c.rows {
        out.row_mut(i).copy_from_slice(&inv_1d(c.row(i)));
    }
    out
}

/// Column-wise analysis: pairs of adjacent rows; output rows [0, n/2) are
/// the low band, [n/2, n) the high band.
pub fn fwd_cols(w: &Matrix) -> Matrix {
    assert!(w.rows % 2 == 0, "column haar needs even row count");
    let h = w.rows / 2;
    let mut out = Matrix::zeros(w.rows, w.cols);
    for k in 0..h {
        for j in 0..w.cols {
            let a = w.get(2 * k, j);
            let b = w.get(2 * k + 1, j);
            out.set(k, j, (a + b) * 0.5);
            out.set(h + k, j, (a - b) * 0.5);
        }
    }
    out
}

pub fn inv_cols(c: &Matrix) -> Matrix {
    assert!(c.rows % 2 == 0);
    let h = c.rows / 2;
    let mut out = Matrix::zeros(c.rows, c.cols);
    for k in 0..h {
        for j in 0..c.cols {
            let lo = c.get(k, j);
            let hi = c.get(h + k, j);
            out.set(2 * k, j, lo + hi);
            out.set(2 * k + 1, j, lo - hi);
        }
    }
    out
}

/// Multi-level row-wise analysis (extension beyond the paper's single level):
/// level ℓ re-transforms the low band of level ℓ-1. Returns the coefficient
/// matrix and the band boundaries [b0=0, b1, ..], where bands are
/// [b_k, b_{k+1}) — deepest low band first, then highs from deep to shallow.
pub fn fwd_rows_multi(w: &Matrix, levels: usize) -> (Matrix, Vec<usize>) {
    assert!(levels >= 1);
    let mut cur = fwd_rows(w);
    let mut low_len = w.cols / 2;
    for _ in 1..levels {
        if low_len % 2 != 0 || low_len < 2 {
            break;
        }
        // transform the low band in place
        let mut next = cur.clone();
        for i in 0..cur.rows {
            let sub = fwd_1d(&cur.row(i)[..low_len]);
            next.row_mut(i)[..low_len].copy_from_slice(&sub);
        }
        cur = next;
        low_len /= 2;
    }
    // band boundaries: [0, low_len, 2*low_len, 4*low_len, ..., cols]
    let mut bounds = vec![0, low_len];
    let mut b = low_len;
    while b < w.cols {
        bounds.push(b * 2);
        b *= 2;
    }
    (cur, bounds)
}

pub fn inv_rows_multi(c: &Matrix, bounds: &[usize]) -> Matrix {
    // bounds = [0, l, 2l, 4l, ..., cols]
    let mut cur = c.clone();
    for w in 1..bounds.len() - 1 {
        let span = bounds[w + 1];
        let mut next = cur.clone();
        for i in 0..cur.rows {
            let sub = inv_1d(&cur.row(i)[..span]);
            next.row_mut(i)[..span].copy_from_slice(&sub);
        }
        cur = next;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn rand_matrix(g: &mut Gen, max_n: usize, max_halfm: usize) -> Matrix {
        let n = g.size(1, max_n);
        let m = 2 * g.size(1, max_halfm);
        let data = g.vec_f32(n * m, 1.5);
        Matrix::from_vec(n, m, data)
    }

    #[test]
    fn known_values() {
        // paper kernels: [1/2,1/2] & [1/2,-1/2]
        let c = fwd_1d(&[3.0, 1.0, -2.0, 4.0]);
        assert_eq!(c, vec![2.0, 1.0, 1.0, -3.0]);
        assert_eq!(inv_1d(&c), vec![3.0, 1.0, -2.0, 4.0]);
    }

    #[test]
    fn prop_roundtrip_rows() {
        check(
            "haar-roundtrip-rows",
            40,
            |g| rand_matrix(g, 40, 33),
            |w| {
                let back = inv_rows(&fwd_rows(w));
                if back.mse(w) < 1e-12 {
                    Ok(())
                } else {
                    Err(format!("mse {}", back.mse(w)))
                }
            },
        );
    }

    #[test]
    fn prop_roundtrip_cols() {
        check(
            "haar-roundtrip-cols",
            40,
            |g| {
                let n = 2 * g.size(1, 20);
                let m = g.size(1, 40);
                Matrix::from_vec(n, m, g.vec_f32(n * m, 1.0))
            },
            |w| {
                let back = inv_cols(&fwd_cols(w));
                if back.mse(w) < 1e-12 {
                    Ok(())
                } else {
                    Err("col roundtrip failed".into())
                }
            },
        );
    }

    #[test]
    fn cols_is_rows_of_transpose() {
        let w = Matrix::from_fn(8, 6, |i, j| (i * 17 + j * 3) as f32 * 0.1 - 2.0);
        let via_t = fwd_rows(&w.transpose()).transpose();
        let direct = fwd_cols(&w);
        assert!(direct.mse(&via_t) < 1e-12);
    }

    #[test]
    fn constant_row_zero_high_band() {
        let w = Matrix::from_vec(1, 8, vec![5.0; 8]);
        let c = fwd_rows(&w);
        assert_eq!(&c.row(0)[..4], &[5.0; 4]);
        assert_eq!(&c.row(0)[4..], &[0.0; 4]);
    }

    #[test]
    fn energy_compaction_on_smooth_signal() {
        // smooth signals put most energy in the low band — the property the
        // quantizer exploits
        let w = Matrix::from_fn(1, 64, |_, j| ((j as f32) * 0.1).sin());
        let c = fwd_rows(&w);
        let lo: f64 = c.row(0)[..32].iter().map(|&x| (x as f64).powi(2)).sum();
        let hi: f64 = c.row(0)[32..].iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(lo > 20.0 * hi, "lo={lo} hi={hi}");
    }

    #[test]
    fn multi_level_roundtrip() {
        let w = Matrix::from_fn(5, 32, |i, j| ((i * j) as f32 * 0.37).cos());
        for levels in 1..=4 {
            let (c, bounds) = fwd_rows_multi(&w, levels);
            let back = inv_rows_multi(&c, &bounds);
            assert!(back.mse(&w) < 1e-10, "levels={levels}");
            assert_eq!(*bounds.last().unwrap(), 32);
        }
    }

    #[test]
    fn multi_level_bounds_shape() {
        let w = Matrix::from_fn(2, 16, |_, j| j as f32);
        let (_, b1) = fwd_rows_multi(&w, 1);
        assert_eq!(b1, vec![0, 8, 16]);
        let (_, b2) = fwd_rows_multi(&w, 2);
        assert_eq!(b2, vec![0, 4, 8, 16]);
    }
}
