fn main() {
    let args = hbllm::util::cli::Args::parse();
    if let Err(e) = hbllm::cli::run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
