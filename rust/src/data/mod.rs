//! Data substrate: byte-level tokenizer (vocab = 256), corpora, evaluation
//! windows, and the QA task binary format written by
//! `python/compile/datagen.py`.

use anyhow::{bail, Context, Result};
use std::path::Path;

pub const TASK_MAGIC: u32 = 0x48425154; // "HBQT"

/// Byte-level "tokenizer": tokens are bytes; kept as a type to document the
/// contract with the model (vocab 256) and centralize pad handling.
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;
    /// newline is the least-harmful pad byte in our corpora
    pub const PAD: u8 = b'\n';

    pub fn encode(text: &str) -> Vec<u8> {
        text.as_bytes().to_vec()
    }

    pub fn decode(tokens: &[u8]) -> String {
        String::from_utf8_lossy(tokens).into_owned()
    }
}

/// A loaded corpus (plain bytes).
pub struct Corpus {
    pub name: String,
    pub data: Vec<u8>,
}

impl Corpus {
    pub fn load(path: &Path) -> Result<Corpus> {
        let data = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(Corpus { name, data })
    }

    /// Non-overlapping evaluation windows of `seq_len` bytes, at most
    /// `max_windows`.
    pub fn windows(&self, seq_len: usize, max_windows: usize) -> Vec<&[u8]> {
        self.data
            .chunks_exact(seq_len)
            .take(max_windows)
            .collect()
    }
}

/// One multiple-choice QA item.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskItem {
    pub prompt: String,
    pub options: Vec<String>,
    pub correct: usize,
}

/// A QA task family loaded from `artifacts/tasks/<family>.bin`.
pub struct TaskFile {
    pub family: String,
    pub items: Vec<TaskItem>,
}

impl TaskFile {
    pub fn load(path: &Path) -> Result<TaskFile> {
        let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        let family = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let mut cur = Cursor { b: &raw, i: 0 };
        let magic = cur.u32()?;
        if magic != TASK_MAGIC {
            bail!("bad task magic {magic:#x} in {path:?}");
        }
        let n = cur.u32()? as usize;
        let mut items = Vec::with_capacity(n);
        for _ in 0..n {
            let plen = cur.u16()? as usize;
            let prompt = cur.str(plen)?;
            let nopt = cur.u8()? as usize;
            let correct = cur.u8()? as usize;
            if correct >= nopt {
                bail!("correct index {correct} out of range ({nopt} options)");
            }
            let mut options = Vec::with_capacity(nopt);
            for _ in 0..nopt {
                let olen = cur.u16()? as usize;
                options.push(cur.str(olen)?);
            }
            items.push(TaskItem { prompt, options, correct });
        }
        Ok(TaskFile { family, items })
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated task file at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn str(&mut self, n: usize) -> Result<String> {
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }
}

/// Pack byte windows into fixed [batch, seq] i32 token batches, padding the
/// final partial batch by repeating the last row (callers track `valid`).
pub struct Batch {
    pub tokens: Vec<i32>, // batch*seq, row-major
    pub batch: usize,
    pub seq: usize,
    /// number of real (non-padding) rows
    pub valid: usize,
}

pub fn batches(windows: &[&[u8]], batch: usize, seq: usize) -> Vec<Batch> {
    let mut out = Vec::new();
    for chunk in windows.chunks(batch) {
        let mut tokens = vec![ByteTokenizer::PAD as i32; batch * seq];
        for (r, win) in chunk.iter().enumerate() {
            for (c, &b) in win.iter().take(seq).enumerate() {
                tokens[r * seq + c] = b as i32;
            }
        }
        // replicate the last real row into padding rows (keeps PJRT shapes
        // fixed without skewing stats — padded rows are masked by `valid`)
        for r in chunk.len()..batch {
            let (src, dst) = tokens.split_at_mut(r * seq);
            dst[..seq].copy_from_slice(&src[(chunk.len() - 1) * seq..chunk.len() * seq]);
        }
        out.push(Batch { tokens, batch, seq, valid: chunk.len() });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_non_overlapping() {
        let c = Corpus { name: "t".into(), data: (0..100u8).collect() };
        let w = c.windows(32, 10);
        assert_eq!(w.len(), 3);
        assert_eq!(w[0][31], 31);
        assert_eq!(w[1][0], 32);
    }

    #[test]
    fn windows_capped() {
        let c = Corpus { name: "t".into(), data: vec![0; 1000] };
        assert_eq!(c.windows(10, 5).len(), 5);
    }

    #[test]
    fn batch_padding() {
        let data: Vec<u8> = (0..50).collect();
        let wins: Vec<&[u8]> = data.chunks_exact(10).collect(); // 5 windows
        let bs = batches(&wins, 4, 10);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0].valid, 4);
        assert_eq!(bs[1].valid, 1);
        // padding rows replicate the last valid row
        assert_eq!(bs[1].tokens[1 * 10], bs[1].tokens[0]);
        assert_eq!(bs[0].tokens[0], 0);
        assert_eq!(bs[0].tokens[39], 39);
    }

    #[test]
    fn task_roundtrip_with_python_format() {
        // byte-level re-encoding of the python writer for one item
        let mut raw = Vec::new();
        raw.extend_from_slice(&TASK_MAGIC.to_le_bytes());
        raw.extend_from_slice(&1u32.to_le_bytes());
        let prompt = b"ta kivo ";
        raw.extend_from_slice(&(prompt.len() as u16).to_le_bytes());
        raw.extend_from_slice(prompt);
        raw.push(2); // options
        raw.push(1); // correct
        for opt in [b"ba.".as_slice(), b"zo.".as_slice()] {
            raw.extend_from_slice(&(opt.len() as u16).to_le_bytes());
            raw.extend_from_slice(opt);
        }
        let dir = std::env::temp_dir().join("hbllm_task_test.bin");
        std::fs::write(&dir, &raw).unwrap();
        let tf = TaskFile::load(&dir).unwrap();
        assert_eq!(tf.items.len(), 1);
        assert_eq!(tf.items[0].correct, 1);
        assert_eq!(tf.items[0].options[0], "ba.");
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn task_rejects_garbage() {
        let dir = std::env::temp_dir().join("hbllm_task_bad.bin");
        std::fs::write(&dir, b"nonsense").unwrap();
        assert!(TaskFile::load(&dir).is_err());
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn real_artifact_tasks_load() {
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tasks"));
        if dir.exists() {
            let mut n = 0;
            for entry in std::fs::read_dir(dir).unwrap() {
                let p = entry.unwrap().path();
                if p.extension().map_or(false, |e| e == "bin") {
                    let tf = TaskFile::load(&p).unwrap();
                    assert!(!tf.items.is_empty());
                    n += 1;
                }
            }
            assert_eq!(n, 9, "expected 9 task families");
        }
    }
}
