//! Linear algebra for the OBQ/GPTQ substrate: Cholesky factorization,
//! triangular solves, and symmetric-positive-definite inversion.
//!
//! Everything runs in f64 internally — the Hessian chain
//! H -> (H + λI)^{-1} -> Cholesky is numerically delicate at f32 and the
//! matrices are small (d ≤ a few thousand).

use super::Matrix;

/// Dense f64 square matrix (internal to linalg).
#[derive(Clone, Debug)]
pub struct Sq {
    pub n: usize,
    pub data: Vec<f64>,
}

impl Sq {
    pub fn zeros(n: usize) -> Sq {
        Sq { n, data: vec![0.0; n * n] }
    }

    pub fn from_matrix(m: &Matrix) -> Sq {
        assert_eq!(m.rows, m.cols);
        Sq { n: m.rows, data: m.data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.n, self.n, self.data.iter().map(|&x| x as f32).collect())
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    pub fn add_diag(&mut self, lambda: f64) {
        for i in 0..self.n {
            self.data[i * self.n + i] += lambda;
        }
    }
}

/// Lower Cholesky factor L with A = L Lᵀ. Fails if A is not SPD (after
/// which callers typically bump the damping and retry).
pub fn cholesky_lower(a: &Sq) -> Result<Sq, String> {
    let n = a.n;
    let mut l = Sq::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(format!("not SPD at pivot {i} (value {sum:.3e})"));
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Sq, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l.get(i, k) * y[k];
        }
        y[i] = sum / l.get(i, i);
    }
    y
}

/// Solve Lᵀ x = y (back substitution), L lower-triangular.
pub fn solve_lower_t(l: &Sq, y: &[f64]) -> Vec<f64> {
    let n = l.n;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l.get(k, i) * x[k];
        }
        x[i] = sum / l.get(i, i);
    }
    x
}

/// SPD inverse via Cholesky: A^{-1} = L^{-T} L^{-1}.
pub fn spd_inverse(a: &Sq) -> Result<Sq, String> {
    let l = cholesky_lower(a)?;
    let n = a.n;
    let mut inv = Sq::zeros(n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e.iter_mut().for_each(|v| *v = 0.0);
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            inv.set(i, j, x[i]);
        }
    }
    Ok(inv)
}

/// The GPTQ factor: upper-triangular U with (H + λI)^{-1} = Uᵀ U.
/// (U = Lᵀ where L is the lower Cholesky factor of the damped inverse.)
/// Retries with escalating damping if the Hessian is near-singular.
pub fn gptq_factor(h: &Sq, lambda_frac: f64) -> Result<Sq, String> {
    let n = h.n;
    let mean_diag = (0..n).map(|i| h.get(i, i)).sum::<f64>() / n as f64;
    let mut lam = (lambda_frac * mean_diag).max(1e-10);
    for _attempt in 0..8 {
        let mut damped = h.clone();
        damped.add_diag(lam);
        match spd_inverse(&damped).and_then(|inv| cholesky_lower(&inv)) {
            Ok(l) => {
                // U = Lᵀ
                let mut u = Sq::zeros(n);
                for i in 0..n {
                    for j in 0..=i {
                        u.set(j, i, l.get(i, j));
                    }
                }
                return Ok(u);
            }
            Err(_) => lam *= 10.0,
        }
    }
    Err("hessian unfactorizable even with heavy damping".into())
}

/// Solve X · U = R for X, with U upper-triangular (kxk), R (n x k).
/// Used for the blockwise OBQ error term E = (W - B) · U_bb^{-1}.
pub fn solve_right_upper(u: &Sq, r: &Matrix) -> Matrix {
    let k = u.n;
    assert_eq!(r.cols, k);
    let mut x = Matrix::zeros(r.rows, k);
    for i in 0..r.rows {
        // forward substitution over columns: X[i,j] = (R[i,j] - Σ_{p<j} X[i,p] U[p,j]) / U[j,j]
        for j in 0..k {
            let mut sum = r.get(i, j) as f64;
            for p in 0..j {
                sum -= x.get(i, p) as f64 * u.get(p, j);
            }
            x.set(i, j, (sum / u.get(j, j)) as f32);
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_spd(n: usize, seed: u64) -> Sq {
        let mut rng = Pcg32::seeded(seed);
        let mut a = Sq::zeros(n);
        // A = G Gᵀ + n·I
        let g: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[i * n + k] * g[j * n + k];
                }
                a.set(i, j, s + if i == j { n as f64 } else { 0.0 });
            }
        }
        a
    }

    fn matmul_sq(a: &Sq, b: &Sq) -> Sq {
        let n = a.n;
        let mut c = Sq::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let av = a.get(i, k);
                for j in 0..n {
                    c.data[i * n + j] += av * b.get(k, j);
                }
            }
        }
        c
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let l = cholesky_lower(&a).unwrap();
        let mut lt = Sq::zeros(12);
        for i in 0..12 {
            for j in 0..12 {
                lt.set(i, j, l.get(j, i));
            }
        }
        let back = matmul_sq(&l, &lt);
        for i in 0..12 {
            for j in 0..12 {
                assert!((back.get(i, j) - a.get(i, j)).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let mut a = Sq::zeros(3);
        a.set(0, 0, -1.0);
        assert!(cholesky_lower(&a).is_err());
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(10, 2);
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul_sq(&a, &inv);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.get(i, j) - want).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn gptq_factor_property() {
        // (H+λI)^{-1} == Uᵀ U
        let h = random_spd(9, 3);
        let u = gptq_factor(&h, 0.01).unwrap();
        let mut damped = h.clone();
        let mean_diag = (0..9).map(|i| h.get(i, i)).sum::<f64>() / 9.0;
        damped.add_diag(0.01 * mean_diag);
        let inv = spd_inverse(&damped).unwrap();
        let mut ut = Sq::zeros(9);
        for i in 0..9 {
            for j in 0..9 {
                ut.set(i, j, u.get(j, i));
            }
        }
        let utu = matmul_sq(&ut, &u);
        for i in 0..9 {
            for j in 0..9 {
                assert!((utu.get(i, j) - inv.get(i, j)).abs() < 1e-8, "({i},{j})");
            }
        }
        // U is upper triangular
        for i in 0..9 {
            for j in 0..i {
                assert_eq!(u.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn solve_right_upper_property() {
        let a = random_spd(6, 4);
        let u = gptq_factor(&a, 0.01).unwrap();
        let r = Matrix::from_fn(3, 6, |i, j| (i as f32 + 1.0) * (j as f32 - 2.0));
        let x = solve_right_upper(&u, &r);
        // X @ U == R
        for i in 0..3 {
            for j in 0..6 {
                let mut s = 0.0f64;
                for p in 0..=j {
                    s += x.get(i, p) as f64 * u.get(p, j);
                }
                assert!((s - r.get(i, j) as f64).abs() < 1e-4, "({i},{j}): {s}");
            }
        }
    }

    #[test]
    fn triangular_solves() {
        let a = random_spd(8, 5);
        let l = cholesky_lower(&a).unwrap();
        let b: Vec<f64> = (0..8).map(|i| i as f64 - 3.0).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // L Lᵀ x == b
        for i in 0..8 {
            let mut s = 0.0;
            for j in 0..8 {
                s += a.get(i, j) * x[j];
            }
            assert!((s - b[i]).abs() < 1e-8);
        }
    }
}
