//! Dense f32 matrix substrate (row-major) — the numeric workhorse for the
//! quantizers, calibration Hessians and the pure-Rust forward pass.

pub mod linalg;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, vals: &[f32]) {
        assert_eq!(vals.len(), self.rows);
        for (i, &v) in vals.iter().enumerate() {
            self.set(i, j, v);
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// C = A @ B (ikj loop order, inner axpy over contiguous rows).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (n, k, m) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(n, m);
        for i in 0..n {
            let a_row = self.row(i);
            let o_row = &mut out.data[i * m..(i + 1) * m];
            for (p, &a) in a_row.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[p * m..(p + 1) * m];
                for (o, &b) in o_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// y = self @ x  (matrix-vector).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(x.iter())
                    .map(|(&a, &b)| a * b)
                    .sum()
            })
            .collect()
    }

    /// self += other * scale
    pub fn add_scaled(&mut self, other: &Matrix, scale: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b * scale;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(other.data.iter()).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Mean squared difference against another matrix.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let n = self.data.len().max(1) as f64;
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / n
    }

    /// Copy of columns [c0, c1).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Matrix::zeros(self.rows, c1 - c0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    pub fn set_cols(&mut self, c0: usize, block: &Matrix) {
        assert_eq!(block.rows, self.rows);
        assert!(c0 + block.cols <= self.cols);
        for i in 0..self.rows {
            self.row_mut(i)[c0..c0 + block.cols].copy_from_slice(block.row(i));
        }
    }

    /// ℓ2 norm of each column.
    pub fn col_l2(&self) -> Vec<f64> {
        let mut acc = vec![0f64; self.cols];
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                acc[j] += (v as f64) * (v as f64);
            }
        }
        acc.into_iter().map(|s| s.sqrt()).collect()
    }

    /// ℓ1 norm of each column.
    pub fn col_l1(&self) -> Vec<f64> {
        let mut acc = vec![0f64; self.cols];
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                acc[j] += v.abs() as f64;
            }
        }
        acc
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let i3 = Matrix::eye(3);
        assert_eq!(a.matmul(&i3), a);
        assert_eq!(i3.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(4, 5, |i, j| (i + 2 * j) as f32 * 0.5);
        let x: Vec<f32> = (0..5).map(|v| v as f32).collect();
        let xm = Matrix::from_vec(5, 1, x.clone());
        let want = a.matmul(&xm).data;
        assert_eq!(a.matvec(&x), want);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_fn(7, 3, |i, j| (i * 31 + j * 7) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn slice_set_roundtrip() {
        let a = Matrix::from_fn(4, 10, |i, j| (i * 10 + j) as f32);
        let blk = a.slice_cols(3, 7);
        assert_eq!(blk.cols, 4);
        let mut b = Matrix::zeros(4, 10);
        b.set_cols(3, &blk);
        assert_eq!(b.get(2, 5), a.get(2, 5));
        assert_eq!(b.get(2, 0), 0.0);
    }

    #[test]
    fn col_norms() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 1.0, 4.0, -1.0]);
        let l2 = a.col_l2();
        assert!((l2[0] - 5.0).abs() < 1e-9);
        let l1 = a.col_l1();
        assert!((l1[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mse_and_frob() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::from_vec(2, 2, vec![1.0; 4]);
        assert!((a.mse(&b) - 1.0).abs() < 1e-12);
        assert!((b.frob_norm() - 2.0).abs() < 1e-12);
    }
}
