//! High-level session API — what examples, the CLI and downstream users
//! call. Owns the artifacts, the FP weights, the (lazily factored)
//! calibration contexts and the PJRT runtime.

use crate::calib::{self, CtxMap};
use crate::coordinator::{quantize_model, LayerResult, QuantJobConfig};
use crate::data::{Corpus, TaskFile};
use crate::engine::{Backend, BackendKind, NativeBackend, PackedModel, XlaBackend};
use crate::eval;
use crate::model::Weights;
use crate::pack;
use crate::quant::Quantizer;
use crate::runtime::{NllRunner, Runtime};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

pub struct Session {
    pub runtime: Runtime,
    pub manifest: Json,
    pub root: PathBuf,
    pub config_name: String,
    pub eval_batch: usize,
    fp_weights: Weights,
    ctxs: Option<CtxMap>,
}

/// Evaluation scope knobs (table harnesses pass smaller values for --quick).
#[derive(Clone, Copy, Debug)]
pub struct EvalScope {
    pub ppl_windows: usize,
    pub qa_items: usize,
    pub calib_windows: usize,
}

impl Default for EvalScope {
    fn default() -> Self {
        EvalScope { ppl_windows: 64, qa_items: 25, calib_windows: 16 }
    }
}

impl Session {
    /// Open the artifacts directory (default `artifacts/`, or $HBLLM_ARTIFACTS).
    pub fn open(root: &Path) -> Result<Session> {
        let manifest_src = std::fs::read_to_string(root.join("manifest.json"))
            .with_context(|| format!("manifest.json missing under {root:?} — run `make artifacts`"))?;
        let manifest = Json::parse(&manifest_src).map_err(|e| anyhow!("manifest: {e}"))?;
        let config_name = manifest
            .get("config")
            .and_then(Json::as_str)
            .unwrap_or("tiny")
            .to_string();
        let eval_batch = manifest.get("eval_batch").and_then(Json::as_usize).unwrap_or(8);
        let weights_rel = manifest
            .at(&["weights", &config_name])
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing weights entry"))?;
        let fp_weights = Weights::load(&root.join(weights_rel))?;
        let runtime = Runtime::new(root)?;
        Ok(Session {
            runtime,
            manifest,
            root: root.to_path_buf(),
            config_name,
            eval_batch,
            fp_weights,
            ctxs: None,
        })
    }

    pub fn default_root() -> PathBuf {
        std::env::var("HBLLM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn fp_weights(&self) -> &Weights {
        &self.fp_weights
    }

    /// Fresh copy of the FP weights (quantization input).
    pub fn clone_weights(&self) -> Weights {
        Weights {
            config: self.fp_weights.config.clone(),
            tensors: self.fp_weights.tensors.clone(),
        }
    }

    /// Calibration contexts (computed once; paper: 128 C4 samples — we use
    /// `calib_windows` windows from the tail of c4s, disjoint from the eval
    /// head).
    pub fn contexts(&mut self, calib_windows: usize) -> Result<&CtxMap> {
        if self.ctxs.is_none() {
            let corpus = self.corpus("c4s")?;
            let seq = self.fp_weights.config.seq_len;
            let n_total = corpus.data.len() / seq;
            anyhow::ensure!(n_total > calib_windows, "c4s too small for calibration");
            let start = n_total - calib_windows;
            let windows: Vec<&[u8]> = (start..n_total)
                .map(|k| &corpus.data[k * seq..(k + 1) * seq])
                .collect();
            let calib = calib::collect(&self.fp_weights, &windows);
            self.ctxs = Some(calib.contexts().map_err(|e| anyhow!("{e}"))?);
        }
        Ok(self.ctxs.as_ref().unwrap())
    }

    pub fn corpus(&self, name: &str) -> Result<Corpus> {
        Corpus::load(&self.root.join("data").join(format!("{name}.bin")))
    }

    pub fn corpora(&self) -> Result<Vec<Corpus>> {
        ["c4s", "wiki2s", "ptbs"].iter().map(|n| self.corpus(n)).collect()
    }

    pub fn tasks(&self) -> Result<Vec<TaskFile>> {
        let fams = self
            .manifest
            .get("task_families")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing task_families"))?;
        fams.iter()
            .filter_map(|f| f.as_str())
            .map(|f| TaskFile::load(&self.root.join("tasks").join(format!("{f}.bin"))))
            .collect()
    }

    /// Quantize a fresh weight copy with `method`.
    pub fn quantize(
        &mut self,
        method: &dyn Quantizer,
        scope: &EvalScope,
        job: &QuantJobConfig,
    ) -> Result<(Weights, Vec<LayerResult>)> {
        self.contexts(scope.calib_windows)?;
        let ctxs = self.ctxs.as_ref().unwrap().clone();
        let mut w = self.clone_weights();
        let results = quantize_model(&mut w, &ctxs, method, job)?;
        Ok((w, results))
    }

    /// NLL runner over the given weights, using the manifest entry point.
    /// `pallas` selects the Pallas-attention HLO (vs the jnp reference one).
    pub fn runner(&self, weights: &Weights, pallas: bool) -> Result<NllRunner> {
        let key = if pallas { "nll" } else { "nll_ref" };
        let entry = self
            .manifest
            .at(&["entry_points", key])
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing entry {key}"))?;
        NllRunner::new(&self.runtime, entry, weights, self.eval_batch)
    }

    /// Full-logits runner (generation).
    pub fn logits_runner(&self, weights: &Weights) -> Result<crate::runtime::LogitsRunner> {
        let entry = self
            .manifest
            .at(&["entry_points", "logits"])
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing logits entry"))?;
        crate::runtime::LogitsRunner::new(&self.runtime, entry, weights, self.eval_batch)
    }

    /// Scoring backend over the given weights (`nll` only for XLA — the
    /// logits HLO entry is a separate compile; use [`Session::gen_backend`]
    /// when `logits`/`decode_step` are needed).
    pub fn backend(&self, weights: &Weights, kind: BackendKind) -> Result<Box<dyn Backend>> {
        match kind {
            BackendKind::Xla { pallas } => {
                Ok(Box::new(XlaBackend::new(self.runner(weights, pallas)?, None)))
            }
            BackendKind::Native { pack } => Ok(Box::new(NativeBackend::new(
                PackedModel::from_weights(weights, pack)?,
                self.eval_batch,
            ))),
        }
    }

    /// Generation-capable backend (`nll` + `logits` + `decode_step`).
    pub fn gen_backend(&self, weights: &Weights, kind: BackendKind) -> Result<Box<dyn Backend>> {
        match kind {
            BackendKind::Xla { pallas } => Ok(Box::new(XlaBackend::new(
                self.runner(weights, pallas)?,
                Some(self.logits_runner(weights)?),
            ))),
            BackendKind::Native { .. } => self.backend(weights, kind),
        }
    }

    /// Serving backend: generation-capable, with `lanes` KV decode lanes
    /// requested for continuous batching. Backends without multi-lane
    /// state (the stateless XLA path) keep a single logical lane; the
    /// generation scheduler adapts to whatever [`Backend::lanes`] reports.
    /// One such backend serves every front-end at once — the TCP line
    /// protocol and the HTTP/SSE endpoints both drive it through the same
    /// engine loop (`coordinator::serve::serve_fronts`; wire spec in
    /// `docs/API.md`, request lifecycle in `docs/ARCHITECTURE.md`).
    ///
    /// `kv_blocks`/`block_len` size the paged KV arena (CLI `--kv-blocks`
    /// / `--block-len`); `None` keeps the backend's worst-case default.
    /// Sizing below worst case is how serving trades memory for admission
    /// backpressure — see [`Backend::set_kv_blocks`].
    pub fn serve_backend(
        &self,
        weights: &Weights,
        kind: BackendKind,
        lanes: usize,
        kv_blocks: Option<usize>,
        block_len: Option<usize>,
    ) -> Result<Box<dyn Backend>> {
        let mut be = self.gen_backend(weights, kind)?;
        be.set_lanes(lanes);
        if kv_blocks.is_some() || block_len.is_some() {
            be.set_kv_blocks(kv_blocks, block_len);
        }
        Ok(be)
    }

    /// Serving model loaded from a saved `.hbq` artifact (CLI `--load`):
    /// the HBQ1 records (`docs/FORMAT.md`) execute as-is on the native
    /// engine — no re-quantization at startup, and bit-identical to the
    /// model that was saved. The artifact stores no model config; the
    /// session's manifest config is used and every record's shape is
    /// validated against it.
    pub fn load_packed(&self, path: &Path) -> Result<PackedModel> {
        let art = pack::format::PackedModel::load(path)?;
        PackedModel::from_artifact(&self.fp_weights.config, &art)
            .with_context(|| format!("artifact {path:?} does not fit the manifest model"))
    }

    /// Native serving backend over a loaded `.hbq` artifact, with `lanes`
    /// KV decode lanes and optional paged-KV geometry — the `--load`
    /// counterpart of [`Session::serve_backend`]. Artifact serving is
    /// native-only: the packed records *are* the execution format, so
    /// there is nothing to hand the XLA path without dequantizing first.
    pub fn loaded_backend(
        &self,
        path: &Path,
        lanes: usize,
        kv_blocks: Option<usize>,
        block_len: Option<usize>,
    ) -> Result<Box<dyn Backend>> {
        let mut be: Box<dyn Backend> =
            Box::new(NativeBackend::new(self.load_packed(path)?, self.eval_batch));
        be.set_lanes(lanes);
        if kv_blocks.is_some() || block_len.is_some() {
            be.set_kv_blocks(kv_blocks, block_len);
        }
        Ok(be)
    }

    /// Full quality evaluation: perplexity on the 3 corpora + AvgQA.
    pub fn evaluate(&self, be: &mut dyn Backend, scope: &EvalScope) -> Result<EvalReport> {
        let mut ppl = Vec::new();
        for corpus in self.corpora()? {
            let p = eval::perplexity(be, &corpus, scope.ppl_windows)?;
            ppl.push((corpus.name.clone(), p));
        }
        let tasks = self.tasks()?;
        let mut qa = Vec::new();
        for t in &tasks {
            qa.push((t.family.clone(), eval::task_accuracy(be, t, scope.qa_items)?));
        }
        let avg_qa = qa.iter().map(|(_, a)| a).sum::<f64>() / qa.len().max(1) as f64;
        Ok(EvalReport { ppl, qa, avg_qa })
    }
}

#[derive(Clone, Debug)]
pub struct EvalReport {
    /// (corpus, perplexity) — c4s, wiki2s, ptbs
    pub ppl: Vec<(String, f64)>,
    pub qa: Vec<(String, f64)>,
    pub avg_qa: f64,
}

impl EvalReport {
    pub fn ppl_of(&self, corpus: &str) -> f64 {
        self.ppl
            .iter()
            .find(|(n, _)| n == corpus)
            .map(|(_, p)| *p)
            .unwrap_or(f64::NAN)
    }

    /// Mean relative PPL against a baseline report (Fig. 1's y-axis).
    pub fn mean_rel_ppl(&self, fp: &EvalReport) -> f64 {
        let mut acc = 0.0;
        for (name, p) in &self.ppl {
            acc += p / fp.ppl_of(name);
        }
        acc / self.ppl.len() as f64
    }
}
