//! End-to-end eval throughput through the PJRT runtime: tokens/s of the
//! batched NLL entry (the L3 hot path after `make artifacts`). Drives the
//! §Perf L3 measurements in EXPERIMENTS.md.

use hbllm::pipeline::Session;
use hbllm::util::bench::{bench, Table};

fn main() -> anyhow::Result<()> {
    let root = Session::default_root();
    let Ok(session) = Session::open(&root) else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return Ok(());
    };
    let corpus = session.corpus("c4s")?;
    let seq = session.fp_weights().config.seq_len;
    let batch = session.eval_batch;

    let mut t = Table::new(&["entry", "batch lat (ms)", "tokens/s"]);
    for (label, pallas) in [("nll_ref (jnp attn)", false), ("nll (pallas attn)", true)] {
        let runner = session.runner(session.fp_weights(), pallas)?;
        let tokens: Vec<i32> = corpus.data[..batch * seq].iter().map(|&b| b as i32).collect();
        // warmup
        runner.nll(&tokens)?;
        let m = bench(label, 2.0, || {
            runner.nll(&tokens).unwrap();
        });
        let tps = (batch * seq) as f64 / m.median_s();
        t.row(&[label.into(), format!("{:.1}", m.median_ms()), format!("{tps:.0}")]);
        eprintln!("[e2e] {label}: {:.1}ms", m.median_ms());
    }
    println!("\n== E2E eval throughput (PJRT CPU, batch {batch} × seq {seq}) ==");
    t.print();
    Ok(())
}
