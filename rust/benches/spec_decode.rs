//! Frequency-cascade speculative decoding: greedy tokens/s and draft
//! acceptance rate vs draft width `k`, against the plain decode baseline
//! on the same packed synth model.
//!
//! The draft reads only the Haar low band of the packed weights (half the
//! binary dots, zero extra storage); the full model verifies `k + 1`
//! positions per round in one batched sweep, so the weight fetch that
//! dominates 1-bit decoding is paid once per round instead of once per
//! token. Every configuration first asserts byte-identical output against
//! the plain baseline — this bench cannot silently trade correctness for
//! speed.
//!
//! Results land in BENCH_spec.json via util::bench::write_json so the
//! trajectory is comparable across commits.
//!
//!     cargo bench --bench spec_decode

use hbllm::engine::{self, Backend, NativeBackend, PackedModel};
use hbllm::model::testing::synth_weights;
use hbllm::util::bench::{bench, write_json, Measurement, Table};
use hbllm::util::json::Json;
use hbllm::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::path::Path;

const N_NEW: usize = 48;
const KS: [usize; 3] = [1, 2, 4];

fn main() -> anyhow::Result<()> {
    // same shape as the serve bench: big enough that per-token GEMV cost
    // dominates, small enough to run without artifacts
    let w = synth_weights(7, 64, 2, 4, 128, 64);
    let cfg = w.config.clone();
    let prompt = b"ta kivo remo ".to_vec();

    let mut measurements: Vec<Measurement> = Vec::new();
    let mut tokens_per_s = BTreeMap::new();
    let mut acceptance = BTreeMap::new();
    let mut table = Table::new(&["config", "tokens/s", "vs plain", "acceptance"]);

    // plain greedy baseline (decode_step path, one token per sweep)
    let mut be = NativeBackend::with_threads(PackedModel::from_weights(&w, true)?, 1, 1);
    let mut rng = Pcg32::seeded(0);
    let reference = engine::generate(&mut be, &prompt, N_NEW, 0.0, &mut rng).unwrap();
    let m = bench("plain", 0.5, || {
        let mut rng = Pcg32::seeded(0);
        std::hint::black_box(
            engine::generate(&mut be, &prompt, N_NEW, 0.0, &mut rng).unwrap(),
        );
    });
    let base_tps = N_NEW as f64 / m.median_s();
    table.row(&[
        "plain".into(),
        format!("{base_tps:.0}"),
        "1.00x".into(),
        "-".into(),
    ]);
    tokens_per_s.insert("plain".to_string(), Json::Num(base_tps));
    measurements.push(m);

    for k in KS {
        let mut be = NativeBackend::with_threads(PackedModel::from_weights(&w, true)?, 1, 1);
        // correctness gate: speculative output must be byte-identical
        let out = engine::generate_spec(&mut be, &prompt, N_NEW, k).unwrap();
        assert_eq!(out, reference, "spec k={k} diverged from plain greedy");
        let m = bench(&format!("spec-k{k}"), 0.5, || {
            std::hint::black_box(engine::generate_spec(&mut be, &prompt, N_NEW, k).unwrap());
        });
        let tps = N_NEW as f64 / m.median_s();
        let st = be.spec_stats().expect("native backend meters speculation");
        let acc = st.acceptance();
        table.row(&[
            format!("spec k={k}"),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / base_tps),
            format!("{:.1}%", 100.0 * acc),
        ]);
        tokens_per_s.insert(format!("spec-k{k}"), Json::Num(tps));
        acceptance.insert(format!("spec-k{k}"), Json::Num(acc));
        measurements.push(m);
    }

    println!(
        "\n== speculative decode ({N_NEW} greedy tokens, packed {} model, low-band draft) ==",
        cfg.name
    );
    table.print();
    println!("\nevery spec config was asserted byte-identical to the plain baseline");
    println!("before timing; acceptance is cumulative over all timed rounds.");

    let context = [
        ("model", Json::Str(cfg.name.clone())),
        ("d_model", Json::Num(cfg.d_model as f64)),
        ("n_layers", Json::Num(cfg.n_layers as f64)),
        ("seq_len", Json::Num(cfg.seq_len as f64)),
        ("n_new", Json::Num(N_NEW as f64)),
        ("tokens_per_s", Json::Obj(tokens_per_s)),
        ("acceptance", Json::Obj(acceptance)),
    ];
    let out = Path::new("BENCH_spec.json");
    write_json(out, &context, &measurements)?;
    println!("\nwrote {}", out.display());
    Ok(())
}
