//! Continuous-batching serve throughput: generated tokens/s vs KV-lane
//! count on the native packed engine.
//!
//! A fixed pool of generation requests drains through the
//! `GenScheduler` + `decode_batch` path at several lane counts. With one
//! lane the requests run back to back; with N lanes each decode step
//! sweeps every packed linear once across all active lanes, so the
//! bit-unpack/weight-traffic cost is amortized and tokens/s should rise
//! with the lane count. No TCP/artifacts involved — the model is
//! synthetic, so this measures the engine + scheduler only.
//!
//! Each lane count also reports its paged-KV arena footprint (the
//! `kv KiB` column / `kv_bytes` in the JSON): with the worst-case default
//! the arena grows linearly with lanes, which is exactly the memory the
//! `--kv-blocks` flag lets serving trade against admission backpressure.
//!
//! Results land in BENCH_serve.json via util::bench::write_json so the
//! trajectory is comparable across commits.
//!
//!     cargo run --release --bench serve_throughput   (or cargo bench)

use hbllm::coordinator::{GenEvent, GenRequest, GenScheduler, Priority};
use hbllm::engine::{Backend, NativeBackend, PackedModel};
use hbllm::model::testing::synth_weights;
use hbllm::util::bench::{bench, write_json, Measurement, Table};
use hbllm::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver};

const MAX_NEW: usize = 16;
const REQUESTS: usize = 8;
const LANE_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Submit every request, drain the scheduler, return tokens produced.
/// Receivers stay alive for the whole drain so no lane is evicted early.
fn run_once(be: &mut dyn Backend, prompts: &[Vec<u8>]) -> usize {
    let mut sched = GenScheduler::new(be.lanes(), MAX_NEW);
    let rxs: Vec<Receiver<GenEvent>> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let (tx, rx) = channel();
            sched.submit(GenRequest {
                prompt: p.clone(),
                max_new: MAX_NEW,
                temperature: 0.0,
                seed: i as u64,
                client: i as u64,
                priority: Priority::Interactive,
                reply: tx,
            });
            rx
        })
        .collect();
    let mut tokens = 0usize;
    while sched.has_work() {
        tokens += sched.step(be);
    }
    drop(rxs);
    tokens
}

fn main() -> anyhow::Result<()> {
    // bigger than micro_weights so the per-token GEMV cost dominates the
    // scheduler overhead, small enough to stay fast without artifacts
    let w = synth_weights(7, 64, 2, 4, 128, 64);
    let cfg = w.config.clone();
    let prompts: Vec<Vec<u8>> = (0..REQUESTS)
        .map(|i| format!("request {i}: ta kivo remo ").into_bytes())
        .collect();
    let expect = REQUESTS * MAX_NEW;

    let mut measurements: Vec<Measurement> = Vec::new();
    let mut tokens_per_s = BTreeMap::new();
    let mut kv_bytes = BTreeMap::new();
    let mut table = Table::new(&["lanes", "tokens/s", "vs 1 lane", "kv KiB"]);
    let mut base_tps = 0.0f64;
    for lanes in LANE_COUNTS {
        let mut be = NativeBackend::with_threads(PackedModel::from_weights(&w, true)?, 1, 1);
        be.set_lanes(lanes);
        let arena_bytes = be.kv_stats().map(|s| s.arena_bytes).unwrap_or(0);
        // warmup + sanity: the full request pool must drain exactly
        assert_eq!(run_once(&mut be, &prompts), expect, "scheduler failed to drain");
        let m = bench(&format!("lanes-{lanes}"), 0.5, || {
            std::hint::black_box(run_once(&mut be, &prompts));
        });
        let tps = expect as f64 / m.median_s();
        if lanes == 1 {
            base_tps = tps;
        }
        table.row(&[
            format!("{lanes}"),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / base_tps),
            format!("{:.0}", arena_bytes as f64 / 1024.0),
        ]);
        tokens_per_s.insert(format!("lanes-{lanes}"), Json::Num(tps));
        kv_bytes.insert(format!("lanes-{lanes}"), Json::Num(arena_bytes as f64));
        measurements.push(m);
    }

    println!(
        "\n== serve throughput ({REQUESTS} requests x {MAX_NEW} tokens, greedy, packed {} model) ==",
        cfg.name
    );
    table.print();
    println!("\neach decode step sweeps the packed linears once across all");
    println!("active lanes; attention and sampling stay per-lane.");

    let context = [
        ("model", Json::Str(cfg.name.clone())),
        ("d_model", Json::Num(cfg.d_model as f64)),
        ("n_layers", Json::Num(cfg.n_layers as f64)),
        ("seq_len", Json::Num(cfg.seq_len as f64)),
        ("requests", Json::Num(REQUESTS as f64)),
        ("max_new", Json::Num(MAX_NEW as f64)),
        ("tokens_per_iter", Json::Num(expect as f64)),
        ("tokens_per_s", Json::Obj(tokens_per_s)),
        ("kv_bytes", Json::Obj(kv_bytes)),
    ];
    let out = Path::new("BENCH_serve.json");
    write_json(out, &context, &measurements)?;
    println!("\nwrote {}", out.display());
    Ok(())
}
