//! Continuous-batching serve throughput: generated tokens/s vs KV-lane
//! count on the native packed engine.
//!
//! A fixed pool of generation requests drains through the
//! `GenScheduler` + `decode_batch` path at several lane counts. With one
//! lane the requests run back to back; with N lanes each decode step
//! sweeps every packed linear once across all active lanes, so the
//! bit-unpack/weight-traffic cost is amortized and tokens/s should rise
//! with the lane count. No TCP/artifacts involved — the model is
//! synthetic, so this measures the engine + scheduler only.
//!
//! Each lane count also reports its paged-KV arena footprint (the
//! `kv KiB` column / `kv_bytes` in the JSON): with the worst-case default
//! the arena grows linearly with lanes, which is exactly the memory the
//! `--kv-blocks` flag lets serving trade against admission backpressure.
//!
//! Results land in BENCH_serve.json via util::bench::write_json so the
//! trajectory is comparable across commits.
//!
//!     cargo run --release --bench serve_throughput   (or cargo bench)

use hbllm::coordinator::{GenEvent, GenRequest, GenScheduler, Priority};
use hbllm::engine::{Backend, NativeBackend, PackedModel};
use hbllm::model::testing::synth_weights;
use hbllm::util::bench::{bench, write_json, Measurement, Table};
use hbllm::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver};

const MAX_NEW: usize = 16;
const REQUESTS: usize = 8;
const LANE_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Submit every request into an existing scheduler, drain it, return
/// tokens produced. Receivers stay alive for the whole drain so no lane
/// is evicted early. Taking the scheduler by reference lets the
/// prefix-cache pass keep its cache warm across bench iterations.
fn run_pool(sched: &mut GenScheduler, be: &mut dyn Backend, prompts: &[Vec<u8>]) -> usize {
    let rxs: Vec<Receiver<GenEvent>> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let (tx, rx) = channel();
            sched.submit(GenRequest {
                id: 1 + i as u64,
                prompt: p.clone(),
                max_new: MAX_NEW,
                temperature: 0.0,
                seed: i as u64,
                client: i as u64,
                priority: Priority::Interactive,
                reply: tx,
            });
            rx
        })
        .collect();
    let mut tokens = 0usize;
    while sched.has_work() {
        tokens += sched.step(be);
    }
    drop(rxs);
    tokens
}

/// One drain through a fresh scheduler (the lane-sweep measurement).
fn run_once(be: &mut dyn Backend, prompts: &[Vec<u8>]) -> usize {
    let mut sched = GenScheduler::new(be.lanes(), MAX_NEW);
    run_pool(&mut sched, be, prompts)
}

fn main() -> anyhow::Result<()> {
    // bigger than micro_weights so the per-token GEMV cost dominates the
    // scheduler overhead, small enough to stay fast without artifacts
    let w = synth_weights(7, 64, 2, 4, 128, 64);
    let cfg = w.config.clone();
    let prompts: Vec<Vec<u8>> = (0..REQUESTS)
        .map(|i| format!("request {i}: ta kivo remo ").into_bytes())
        .collect();
    let expect = REQUESTS * MAX_NEW;

    let mut measurements: Vec<Measurement> = Vec::new();
    let mut tokens_per_s = BTreeMap::new();
    let mut kv_bytes = BTreeMap::new();
    let mut table = Table::new(&["lanes", "tokens/s", "vs 1 lane", "kv KiB"]);
    let mut base_tps = 0.0f64;
    for lanes in LANE_COUNTS {
        let mut be = NativeBackend::with_threads(PackedModel::from_weights(&w, true)?, 1, 1);
        be.set_lanes(lanes);
        let arena_bytes = be.kv_stats().map(|s| s.arena_bytes).unwrap_or(0);
        // warmup + sanity: the full request pool must drain exactly
        assert_eq!(run_once(&mut be, &prompts), expect, "scheduler failed to drain");
        let m = bench(&format!("lanes-{lanes}"), 0.5, || {
            std::hint::black_box(run_once(&mut be, &prompts));
        });
        let tps = expect as f64 / m.median_s();
        if lanes == 1 {
            base_tps = tps;
        }
        table.row(&[
            format!("{lanes}"),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / base_tps),
            format!("{:.0}", arena_bytes as f64 / 1024.0),
        ]);
        tokens_per_s.insert(format!("lanes-{lanes}"), Json::Num(tps));
        kv_bytes.insert(format!("lanes-{lanes}"), Json::Num(arena_bytes as f64));
        measurements.push(m);
    }

    // Prefix-cache pass: one bare preamble plus extensions of it (the
    // repeat-system-prompt traffic shape). With the radix prompt cache
    // on, the finished preamble's KV blocks stay resident, so every
    // extension admission maps them read-only and prefills only its
    // tail — the measured delta is the amortized prefill cost. The
    // scheduler (and so the warm cache) persists across bench
    // iterations, like a long-lived server seeing repeat prompts.
    let preamble = b"ta kivo remo ta kivo remo ".to_vec();
    let extensions: Vec<Vec<u8>> = (0..REQUESTS)
        .map(|i| {
            if i == 0 {
                preamble.clone()
            } else {
                let mut p = preamble.clone();
                p.extend_from_slice(format!("t{i}").as_bytes());
                p
            }
        })
        .collect();
    let mut cache_tps = BTreeMap::new();
    let mut cache_hit_rate = 0.0f64;
    for capacity in [0usize, 4] {
        let key = if capacity == 0 { "prefix-cache-off" } else { "prefix-cache-on" };
        let mut be = NativeBackend::with_threads(PackedModel::from_weights(&w, true)?, 1, 1);
        be.set_lanes(4);
        let mut sched = GenScheduler::new(be.lanes(), MAX_NEW);
        sched.set_prefix_cache(capacity);
        // warmup doubles as the cache seed: the preamble finishes and
        // parks its blocks, so measured iterations run hit-steady
        assert_eq!(run_pool(&mut sched, &mut be, &extensions), expect, "cache pass failed to drain");
        let m = bench(key, 0.5, || {
            std::hint::black_box(run_pool(&mut sched, &mut be, &extensions));
        });
        let tps = expect as f64 / m.median_s();
        if capacity > 0 {
            let (hits, misses) = (
                sched.metrics().prefix_cache_hits.get(),
                sched.metrics().prefix_cache_misses.get(),
            );
            cache_hit_rate = hits as f64 / (hits + misses).max(1) as f64;
        }
        sched.flush_prefix_cache(&mut be);
        if let Some(st) = be.kv_stats() {
            assert_eq!(st.free_blocks, st.total_blocks, "cache pass leaked kv blocks");
        }
        cache_tps.insert(key.to_string(), Json::Num(tps));
        measurements.push(m);
    }

    println!(
        "\n== serve throughput ({REQUESTS} requests x {MAX_NEW} tokens, greedy, packed {} model) ==",
        cfg.name
    );
    table.print();
    println!("\neach decode step sweeps the packed linears once across all");
    println!("active lanes; attention and sampling stay per-lane.");

    let (off, on) = (
        cache_tps.get("prefix-cache-off").and_then(Json::as_f64).unwrap_or(0.0),
        cache_tps.get("prefix-cache-on").and_then(Json::as_f64).unwrap_or(0.0),
    );
    println!(
        "\nrepeat-prompt pool, 4 lanes: {off:.0} tok/s cache-off vs {on:.0} \
         tok/s cache-on ({:.1}% admissions hit; prefill skipped on hits)",
        100.0 * cache_hit_rate
    );

    let context = [
        ("model", Json::Str(cfg.name.clone())),
        ("d_model", Json::Num(cfg.d_model as f64)),
        ("n_layers", Json::Num(cfg.n_layers as f64)),
        ("seq_len", Json::Num(cfg.seq_len as f64)),
        ("requests", Json::Num(REQUESTS as f64)),
        ("max_new", Json::Num(MAX_NEW as f64)),
        ("tokens_per_iter", Json::Num(expect as f64)),
        ("tokens_per_s", Json::Obj(tokens_per_s)),
        ("kv_bytes", Json::Obj(kv_bytes)),
        ("prefix_cache_tokens_per_s", Json::Obj(cache_tps)),
        ("prefix_cache_hit_rate", Json::Num(cache_hit_rate)),
    ];
    let out = Path::new("BENCH_serve.json");
    write_json(out, &context, &measurements)?;
    println!("\nwrote {}", out.display());
    Ok(())
}
