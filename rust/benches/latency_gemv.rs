//! §4.5 reproduction: GEMV latency of 1-bit packed weights vs f32, on the
//! OPT-175B layer shapes the paper measures (d = 12288).
//!
//! Paper claim: quantized inference ≈ 31.8% of the FP16 baseline time —
//! a memory-bandwidth argument (32× less weight traffic) that applies on
//! CPU just as on the P100. We report f32 GEMV vs packed-binary GEMV vs
//! the fused Haar-domain GEMV (HBLLM deployment kernel).

use hbllm::pack::{HaarPackedLinear, PackedLinear};
use hbllm::tensor::Matrix;
use hbllm::util::bench::{bench, black_box, Table};
use hbllm::util::rng::Pcg32;

fn main() {
    println!(
        "[latency] packed-GEMV kernel: {}",
        hbllm::pack::kernels::active().name
    );
    // OPT-175B shapes: attention d×d and MLP d×4d (scaled-down variants
    // first so the table also runs quickly on small machines)
    let shapes = [
        ("2048x2048", 2048usize, 2048usize),
        ("4096x4096", 4096, 4096),
        ("12288x12288", 12288, 12288),
    ];
    let mut t = Table::new(&["shape", "f32 (ms)", "binary (ms)", "haar-fused (ms)", "binary/f32", "haar/f32"]);
    for (label, n, m) in shapes {
        let mut rng = Pcg32::seeded(42);
        let w = Matrix::from_fn(n, m, |_, _| rng.normal_f32() * 0.02);
        let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0f32; n];

        let mf = bench(label, 0.8, || {
            // f32 GEMV baseline
            let yy = w.matvec(&x);
            black_box(yy[0]);
        });

        let packed = PackedLinear::from_dense(&w);
        let mb = bench(label, 0.8, || {
            packed.gemv(&x, &mut y);
            black_box(y[0]);
        });

        let hp = HaarPackedLinear::from_dense(&w).expect("bench shapes have even width");
        let mh = bench(label, 0.8, || {
            hp.gemv(&x, &mut y);
            black_box(y[0]);
        });

        t.row(&[
            label.into(),
            format!("{:.2}", mf.median_ms()),
            format!("{:.2}", mb.median_ms()),
            format!("{:.2}", mh.median_ms()),
            format!("{:.1}%", 100.0 * mb.median_ns / mf.median_ns),
            format!("{:.1}%", 100.0 * mh.median_ns / mf.median_ns),
        ]);
        eprintln!("[latency] {label} done");
    }
    println!("\n== §4.5: GEMV latency, 1-bit packed vs f32 (single thread) ==");
    t.print();
    println!("\npaper claim: quantized ≈ 31.8% of FP16 latency; the Haar-fused");
    println!("kernel adds only the O(d) activation butterfly on top of binary.");
}
