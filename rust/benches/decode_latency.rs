//! Per-token decode latency: the native packed engine's KV-cached
//! incremental path vs full-window re-forward baselines.
//!
//! Three rows (greedy decoding, identical outputs per backend):
//!   native-kv    — engine decode_step, one packed GEMV sweep per token
//!   native-full  — same engine, cache dropped before every token (the
//!                  cost of not having a KV cache, hardware held fixed)
//!   xla-window   — the fixed-shape PJRT logits entry re-forwarding the
//!                  whole window per token (skipped when artifacts/ are
//!                  missing, e.g. in CI)
//!
//! Results land in BENCH_engine.json via util::bench::write_json so the
//! trajectory is comparable across commits.
//!
//!     cargo run --release --bench decode_latency   (or cargo bench)

use hbllm::engine::{self, Backend, BackendKind, NativeBackend, PackedModel};
use hbllm::model::testing::micro_weights;
use hbllm::pipeline::Session;
use hbllm::util::bench::{bench, write_json, Measurement, Table};
use hbllm::util::json::Json;
use hbllm::util::rng::Pcg32;
use std::path::Path;

const PROMPT: &[u8] = b"ta ki";
const N_NEW: usize = 6;

/// Greedy-decode N_NEW tokens; returns the decoded text (kept out of the
/// timed closure's dead-code path via black_box at the call sites).
fn decode(be: &mut dyn Backend, full_reforward: bool) -> Vec<u8> {
    let mut rng = Pcg32::seeded(0);
    if !full_reforward {
        be.reset();
        return engine::generate(be, PROMPT, N_NEW, 0.0, &mut rng).unwrap();
    }
    let mut text = PROMPT.to_vec();
    for _ in 0..N_NEW {
        be.reset(); // drop the cache: every token pays a full prefill
        let row = be.decode_step(&text).unwrap();
        let next = engine::sample_logits(&row, 0.0, &mut rng);
        text.push(next as u8);
    }
    text
}

fn per_token_us(m: &Measurement) -> f64 {
    m.median_ns / 1e3 / N_NEW as f64
}

fn main() -> anyhow::Result<()> {
    let w = micro_weights(42);
    let cfg = w.config.clone();
    let mut measurements: Vec<Measurement> = Vec::new();
    let mut t = Table::new(&["backend", "per-token (us)", "vs native-kv"]);

    let mut native = NativeBackend::new(PackedModel::from_weights(&w, true)?, 1);
    let sample = decode(&mut native, false);
    eprintln!("[decode] native sample: {:?}", String::from_utf8_lossy(&sample));
    let m_kv = bench("native-kv", 1.0, || {
        std::hint::black_box(decode(&mut native, false));
    });
    let m_full = bench("native-full", 1.0, || {
        std::hint::black_box(decode(&mut native, true));
    });

    // XLA baseline needs compiled artifacts; skip gracefully without them
    let m_xla = match Session::open(&Session::default_root()) {
        Ok(session) => {
            let mut be =
                session.gen_backend(session.fp_weights(), BackendKind::Xla { pallas: false })?;
            decode(be.as_mut(), true); // warmup (compile + first run)
            Some(bench("xla-window", 2.0, || {
                std::hint::black_box(decode(be.as_mut(), true));
            }))
        }
        Err(_) => {
            eprintln!("SKIP xla-window: artifacts missing — run `make artifacts`");
            None
        }
    };

    let base = per_token_us(&m_kv);
    for m in [Some(&m_kv), Some(&m_full), m_xla.as_ref()].into_iter().flatten() {
        t.row(&[
            m.name.clone(),
            format!("{:.1}", per_token_us(m)),
            format!("{:.2}x", per_token_us(m) / base),
        ]);
        measurements.push(m.clone());
    }

    println!("\n== per-token decode latency (greedy, {} new tokens) ==", N_NEW);
    t.print();
    println!("\nnative-kv pays one packed GEMV sweep + O(t*d) attention per");
    println!("token; the full-window baselines re-forward every position.");

    let context = [
        ("model", Json::Str(cfg.name.clone())),
        ("d_model", Json::Num(cfg.d_model as f64)),
        ("n_layers", Json::Num(cfg.n_layers as f64)),
        ("seq_len", Json::Num(cfg.seq_len as f64)),
        ("prompt_bytes", Json::Num(PROMPT.len() as f64)),
        ("new_tokens", Json::Num(N_NEW as f64)),
    ];
    let out = Path::new("BENCH_engine.json");
    write_json(out, &context, &measurements)?;
    println!("\nwrote {}", out.display());
    Ok(())
}
