//! Table 3 reproduction: quantization wall-clock per method per model size.
//!
//! Paper shape (LLaMA-1 7B/13B/30B on 4×3090): PB-LLM < FrameQuant < BiLLM
//! < HBLLM (≈1.2–1.3× BiLLM) < ARB-RC < ARB-X; HBLLM scales to sizes ARB/
//! FrameQuant cannot. Here: synthetic LLM-like layer sets at three dims.

use hbllm::quant::{by_name, synth};
use hbllm::util::bench::Table;
use std::time::Instant;

fn main() {
    // (label, n, m, layers) — one layer-set quantization per cell
    let sizes = [("d256", 256usize, 256usize, 4usize), ("d512", 512, 512, 2), ("d768", 768, 768, 1)];
    let methods = ["pb-llm", "framequant-1.1", "billm", "hbllm-row", "hbllm-col", "arb-rc", "arb-x"];

    // pre-generate layers + Hessian factorizations (shared across methods,
    // exactly like the real pipeline shares `Session::contexts`)
    eprintln!("[table3] generating layer sets...");
    let layer_sets: Vec<Vec<_>> = sizes
        .iter()
        .map(|&(_, n, m, layers)| {
            (0..layers)
                .map(|l| synth::llm_like_layer(n, m, 100 + l as u64))
                .collect()
        })
        .collect();

    let mut t = Table::new(&["method", "d256 (s)", "d512 (s)", "d768 (s)", "vs billm @d512"]);
    let mut billm_d512 = 0.0f64;
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for name in methods {
        let q = by_name(name).unwrap();
        let mut secs = Vec::new();
        for set in &layer_sets {
            let t0 = Instant::now();
            for (w, ctx) in set {
                let out = q.quantize(w, ctx);
                std::hint::black_box(out.mse);
            }
            secs.push(t0.elapsed().as_secs_f64());
        }
        if name == "billm" {
            billm_d512 = secs[1];
        }
        eprintln!("[table3] {name}: {secs:?}");
        rows.push((name.to_string(), secs));
    }
    for (name, secs) in rows {
        t.row(&[
            name.clone(),
            format!("{:.2}", secs[0]),
            format!("{:.2}", secs[1]),
            format!("{:.2}", secs[2]),
            format!("{:.2}x", secs[1] / billm_d512.max(1e-9)),
        ]);
    }
    println!("\n== Table 3: quantization time (synthetic layer sets; excludes shared Hessian factorization) ==");
    t.print();
    println!("\npaper claim to check: HBLLM ≈ 1.2–1.3× BiLLM; ARB variants slowest.");
}
