//! §3.6 reproduction: transform cost scaling — local Haar O(d) vs global
//! orthogonal (FrameQuant butterfly ≈ O(d log d), dense rotation O(d²)).

use hbllm::haar;
use hbllm::quant::framequant::Butterfly;
use hbllm::tensor::Matrix;
use hbllm::util::bench::{bench, black_box, Table};
use hbllm::util::rng::Pcg32;

fn main() {
    let dims = [512usize, 1024, 2048, 4096, 8192];
    let mut t = Table::new(&["d", "haar (µs)", "butterfly (µs)", "dense-rot (µs)", "haar ratio vs dense"]);
    for &d in &dims {
        let mut rng = Pcg32::seeded(1);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();

        let mh = bench("haar", 0.3, || {
            black_box(haar::fwd_1d(&x)[0]);
        });
        let bf = Butterfly::new(d, 3, 3);
        let mb = bench("butterfly", 0.3, || {
            black_box(bf.fwd(&x)[0]);
        });
        // dense rotation row: one d×d matvec (what a global orthogonal
        // transform costs at dequantization time, per §2.3)
        let rot = Matrix::from_fn(1024.min(d), d, |_, _| rng.normal_f32());
        let scale = d as f64 / rot.rows as f64; // extrapolate to full d×d
        let md = bench("dense", 0.3, || {
            black_box(rot.matvec(&x)[0]);
        });
        t.row(&[
            format!("{d}"),
            format!("{:.1}", mh.median_ns / 1e3),
            format!("{:.1}", mb.median_ns / 1e3),
            format!("{:.1}", md.median_ns / 1e3 * scale),
            format!("{:.0}x", md.median_ns * scale / mh.median_ns),
        ]);
        eprintln!("[haar_cost] d={d} done");
    }
    println!("\n== §3.6: transform cost — O(d) Haar vs O(d²) global rotation ==");
    t.print();
    println!("\nthe gap must GROW linearly with d (paper's deployment argument).");
}
