//! Quickstart: quantize one synthetic LLM-like layer with HBLLM and the
//! baselines, compare reconstruction error, W-bits and CIQ — then run the
//! native packed-weight engine end to end (KV-cached decode from 1-bit
//! weights) on a synthetic micro model.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts needed — this exercises the pure quantization library and
//! the native serving backend.

use hbllm::engine::{self, Backend, NativeBackend, PackedModel};
use hbllm::model::testing::micro_weights;
use hbllm::model::{forward, nll_from_logits};
use hbllm::quant::{by_name, ciq, synth, table_methods};
use hbllm::util::bench::Table;
use hbllm::util::fmt_sig;
use hbllm::util::rng::Pcg32;

fn main() {
    // A 256×512 layer with heavy tails + planted outlier columns, and a
    // correlated calibration Hessian — the structure real LLM layers show.
    let (w, ctx) = synth::llm_like_layer(256, 512, 42);
    println!(
        "synthetic layer: {}x{} (max |w| = {:.2})\n",
        w.rows,
        w.cols,
        w.max_abs()
    );

    let mut t = Table::new(&["method", "W-bits@7B", "rel-MSE", "CIQ max", "CIQ mean"]);
    let w_norm = w.frob_norm().powi(2) / (w.rows * w.cols) as f64;
    for name in table_methods() {
        let q = by_name(name).unwrap();
        let out = q.quantize(&w, &ctx);
        t.row(&[
            name.to_string(),
            fmt_sig(q.avg_wbits(4096, 4096), 4),
            fmt_sig(out.mse / w_norm, 3),
            format!("{}", ciq::row_ciq_max(&out.w_hat)),
            format!("{:.1}", ciq::row_ciq_mean(&out.w_hat)),
        ]);
    }
    t.print();
    println!("\nLower rel-MSE at ~1.1 bits is the paper's claim: the Haar");
    println!("transform + structure-aware grouping buys expressiveness (CIQ)");
    println!("that plain binarization cannot reach.");

    // --- native packed engine: serve a micro model from its 1-bit form ---
    let w = micro_weights(7);
    let packed = PackedModel::from_weights(&w, true).expect("even dims");
    let dense_bytes = PackedModel::from_weights(&w, false).unwrap().linear_bytes();
    println!("\n== native engine (packed 1-bit serving, KV-cached decode) ==");
    println!(
        "linear payload: {} B packed vs {} B fp32 ({:.1}x smaller)",
        packed.linear_bytes(),
        dense_bytes,
        dense_bytes as f64 / packed.linear_bytes() as f64
    );
    // per-position NLL through the engine vs its own dequantized reference
    let reference = packed.to_weights();
    let mut be = NativeBackend::new(packed, 1);
    let seq = w.config.seq_len;
    let phrase = b"ta kivo remo ";
    let window: Vec<u8> = (0..seq).map(|i| phrase[i % phrase.len()]).collect();
    let tokens: Vec<i32> = window.iter().map(|&b| b as i32).collect();
    let nll_engine = be.nll(&tokens).expect("engine nll");
    let nll_ref = nll_from_logits(&forward(&reference, &window, None), &window);
    let max_diff = nll_engine
        .iter()
        .zip(&nll_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("packed forward vs dequantized reference: max |Δnll| = {max_diff:.2e}");
    let mut rng = Pcg32::seeded(0);
    let out = engine::generate(&mut be, b"ta ", 24, 0.0, &mut rng).expect("generate");
    println!("greedy sample: {:?}", String::from_utf8_lossy(&out));
}
