//! Quickstart: quantize one synthetic LLM-like layer with HBLLM and the
//! baselines, compare reconstruction error, W-bits and CIQ.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts needed — this exercises the pure quantization library.

use hbllm::quant::{by_name, ciq, synth, table_methods};
use hbllm::util::bench::Table;
use hbllm::util::fmt_sig;

fn main() {
    // A 256×512 layer with heavy tails + planted outlier columns, and a
    // correlated calibration Hessian — the structure real LLM layers show.
    let (w, ctx) = synth::llm_like_layer(256, 512, 42);
    println!(
        "synthetic layer: {}x{} (max |w| = {:.2})\n",
        w.rows,
        w.cols,
        w.max_abs()
    );

    let mut t = Table::new(&["method", "W-bits@7B", "rel-MSE", "CIQ max", "CIQ mean"]);
    let w_norm = w.frob_norm().powi(2) / (w.rows * w.cols) as f64;
    for name in table_methods() {
        let q = by_name(name).unwrap();
        let out = q.quantize(&w, &ctx);
        t.row(&[
            name.to_string(),
            fmt_sig(q.avg_wbits(4096, 4096), 4),
            fmt_sig(out.mse / w_norm, 3),
            format!("{}", ciq::row_ciq_max(&out.w_hat)),
            format!("{:.1}", ciq::row_ciq_mean(&out.w_hat)),
        ]);
    }
    t.print();
    println!("\nLower rel-MSE at ~1.1 bits is the paper's claim: the Haar");
    println!("transform + structure-aware grouping buys expressiveness (CIQ)");
    println!("that plain binarization cannot reach.");
}
