//! END-TO-END driver (the repo's full-stack validation): load the tiny GPT
//! trained at build time, calibrate on c4s, quantize every linear layer
//! with HBLLM-row + key baselines, and evaluate perplexity on the three
//! corpora and accuracy on the 9 QA families — all through the AOT HLO
//! modules on the PJRT runtime (Python is not involved).
//!
//!     make artifacts && cargo run --release --example e2e_quant_eval
//!
//! Flags: --quick (smaller eval), --methods a,b,c, --pallas (use the
//! Pallas-attention HLO entry), --backend xla|native (serving backend for
//! the quantized rows; fp32 always scores through XLA here).

use hbllm::coordinator::scheduler::aggregate_wbits;
use hbllm::engine::BackendKind;
use hbllm::coordinator::QuantJobConfig;
use hbllm::pipeline::{EvalScope, Session};
use hbllm::quant;
use hbllm::util::bench::Table;
use hbllm::util::cli::Args;
use hbllm::util::fmt_sig;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let root = Session::default_root();
    let mut session = Session::open(&root)?;
    let quick = args.has_flag("quick");
    let scope = if quick {
        EvalScope { ppl_windows: 16, qa_items: 8, calib_windows: 8 }
    } else {
        EvalScope::default()
    };
    let pallas = args.has_flag("pallas");
    let backend_name = args.get_or("backend", "xla").to_string();
    let methods: Vec<String> = args
        .get("methods")
        .map(|s| s.split(',').map(String::from).collect())
        .unwrap_or_else(|| {
            vec!["billm".into(), "arb-rc".into(), "hbllm-row".into(), "hbllm-col".into()]
        });
    let job = QuantJobConfig { quiet: true, ..Default::default() };

    let cfg = session.fp_weights().config.clone();
    println!(
        "model: {} ({:.2}M params), eval entry: {}, scope: {} ppl-windows / {} qa-items\n",
        cfg.name,
        session.fp_weights().total_elements() as f64 / 1e6,
        if pallas { "pallas-attention HLO" } else { "jnp-attention HLO" },
        scope.ppl_windows,
        scope.qa_items,
    );

    let t0 = Instant::now();
    let mut fp_be = session.backend(session.fp_weights(), BackendKind::Xla { pallas })?;
    let fp = session.evaluate(fp_be.as_mut(), &scope)?;
    println!("fp32 eval done in {:.1}s", t0.elapsed().as_secs_f64());

    let mut t = Table::new(&[
        "method", "W-bits", "c4s", "wiki2s", "ptbs", "AvgQA", "relPPL", "quant-s",
    ]);
    t.row(&[
        "fp32".into(),
        "32.00".into(),
        fmt_sig(fp.ppl_of("c4s"), 4),
        fmt_sig(fp.ppl_of("wiki2s"), 4),
        fmt_sig(fp.ppl_of("ptbs"), 4),
        format!("{:.1}%", 100.0 * fp.avg_qa),
        "1.00".into(),
        "-".into(),
    ]);

    for name in &methods {
        let method = quant::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown method {name}"))?;
        let tq = Instant::now();
        let (qw, results) = session.quantize(method.as_ref(), &scope, &job)?;
        let quant_s = tq.elapsed().as_secs_f64();
        // only hbllm weights have the packed deployment form; other
        // baselines serve dense through the native engine
        let q_kind = BackendKind::parse(&backend_name, pallas, name.starts_with("hbllm"))?;
        let mut be = session.backend(&qw, q_kind)?;
        let rep = session.evaluate(be.as_mut(), &scope)?;
        t.row(&[
            name.clone(),
            fmt_sig(aggregate_wbits(&results), 4),
            fmt_sig(rep.ppl_of("c4s"), 4),
            fmt_sig(rep.ppl_of("wiki2s"), 4),
            fmt_sig(rep.ppl_of("ptbs"), 4),
            format!("{:.1}%", 100.0 * rep.avg_qa),
            fmt_sig(rep.mean_rel_ppl(&fp), 3),
            format!("{quant_s:.1}"),
        ]);
        println!("{name}: done ({quant_s:.1}s quant)");
    }
    println!();
    t.print();
    println!("\ntotal {:.1}s — recorded in EXPERIMENTS.md §E2E", t0.elapsed().as_secs_f64());
    Ok(())
}
