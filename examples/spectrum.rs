//! Appendix B/C analog: *why* the Haar transform helps binarization.
//!
//! For each trained linear layer we measure, per row:
//!   * band energy split (low vs high Haar band),
//!   * kurtosis before vs after the transform (binarization error of the
//!     optimal 1-bit fit grows with |kurtosis - 1|; sign quantization is
//!     exact iff |v - μ| is constant),
//!   * the optimal single-group 1-bit relative error in weight space vs
//!     Haar space vs Haar space with the 2-group split.
//!
//!     cargo run --release --example spectrum

use hbllm::haar;
use hbllm::pipeline::Session;
use hbllm::quant::{binarize, grouping};
use hbllm::tensor::Matrix;
use hbllm::util::bench::Table;

fn rel_err_1bit(rows: &Matrix) -> f64 {
    let mut err = 0f64;
    let mut sig = 0f64;
    for i in 0..rows.rows {
        let (p, e) = binarize::fit_and_error(rows.row(i).iter().copied());
        let _ = p;
        err += e;
        sig += rows.row(i).iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
    }
    err / sig.max(1e-30)
}

fn rel_err_grouped(rows: &Matrix) -> f64 {
    let mut err = 0f64;
    let mut sig = 0f64;
    for i in 0..rows.rows {
        let vals = rows.row(i);
        let (_, e) = grouping::fit_row_oracle(vals, 40, true);
        err += e;
        sig += vals.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
    }
    err / sig.max(1e-30)
}

fn kurtosis(vals: &[f32]) -> f64 {
    let n = vals.len() as f64;
    let mean = vals.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = vals.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    if var <= 0.0 {
        return 0.0;
    }
    vals.iter().map(|&v| (v as f64 - mean).powi(4)).sum::<f64>() / n / var.powi(2)
}

fn main() -> anyhow::Result<()> {
    let session = Session::open(&Session::default_root())?;
    let w = session.fp_weights();
    let mut t = Table::new(&[
        "layer", "lo-energy", "kurt(W)", "kurt(haar)", "err 1bit W",
        "err 1bit haar", "err 2grp haar",
    ]);
    for name in ["l0.wq", "l0.w1", "l2.wo", "l3.w2"] {
        let mat = w.get(name).as_mat().transpose(); // paper orientation
        let c = haar::fwd_rows(&mat);
        let h = c.cols / 2;
        let lo: f64 = (0..c.rows)
            .map(|i| c.row(i)[..h].iter().map(|&v| (v as f64).powi(2)).sum::<f64>())
            .sum();
        let hi: f64 = (0..c.rows)
            .map(|i| c.row(i)[h..].iter().map(|&v| (v as f64).powi(2)).sum::<f64>())
            .sum();
        t.row(&[
            name.into(),
            format!("{:.1}%", 100.0 * lo / (lo + hi)),
            format!("{:.2}", kurtosis(&mat.data)),
            format!("{:.2}", kurtosis(&c.data)),
            format!("{:.3}", rel_err_1bit(&mat)),
            format!("{:.3}", rel_err_1bit(&c)),
            format!("{:.3}", rel_err_grouped(&c)),
        ]);
    }
    println!("== Weight spectrum analysis (appendix B/C analog, trained tiny GPT) ==");
    t.print();
    println!("\nreading: the 2-group split in the Haar domain (last column) is the");
    println!("mechanism behind HBLLM's CIQ gain — it must beat both 1-bit columns.");
    Ok(())
}
