//! Table 1 + Figure 1 reproduction: perplexity (c4s/wiki2s/ptbs), AvgQA and
//! W-bits for every method the paper tables list, on the tiny GPT.
//!
//!     cargo run --release --example table1 [-- --quick] [-- --fig1]
//!
//! Paper shape to verify (not absolute numbers — see DESIGN.md
//! §Substitutions): FullPrecision < HBLLM-row ≲ HBLLM-col < ARB-RC < ARB-X
//! ≈ BiLLM ≪ PB-LLM on perplexity; FrameQuant competitive but at 2.2 bits;
//! HBLLM W-bits lowest among 1-bit methods.

use hbllm::coordinator::scheduler::aggregate_wbits;
use hbllm::coordinator::QuantJobConfig;
use hbllm::pipeline::{EvalScope, Session};
use hbllm::quant;
use hbllm::util::bench::Table;
use hbllm::util::cli::Args;
use hbllm::util::fmt_sig;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let mut session = Session::open(&Session::default_root())?;
    let scope = if args.has_flag("quick") {
        EvalScope { ppl_windows: 16, qa_items: 8, calib_windows: 8 }
    } else {
        EvalScope::default()
    };
    let job = QuantJobConfig { quiet: true, ..Default::default() };

    let kind = hbllm::engine::BackendKind::Xla { pallas: false };
    let mut fp_be = session.backend(session.fp_weights(), kind)?;
    let fp = session.evaluate(fp_be.as_mut(), &scope)?;

    let mut t1 = Table::new(&["method", "W-bits", "W-bits@7B", "c4s", "wiki2s", "ptbs", "AvgQA"]);
    t1.row(&[
        "FullPrecision".into(),
        "32.00".into(),
        "16.00".into(),
        fmt_sig(fp.ppl_of("c4s"), 4),
        fmt_sig(fp.ppl_of("wiki2s"), 4),
        fmt_sig(fp.ppl_of("ptbs"), 4),
        format!("{:.2}%", 100.0 * fp.avg_qa),
    ]);

    let mut fig1: Vec<(String, f64)> = Vec::new();
    for name in quant::table_methods() {
        let method = quant::by_name(name).unwrap();
        let (qw, results) = session.quantize(method.as_ref(), &scope, &job)?;
        let mut be = session.backend(&qw, kind)?;
        let rep = session.evaluate(be.as_mut(), &scope)?;
        t1.row(&[
            name.into(),
            fmt_sig(aggregate_wbits(&results), 4),
            fmt_sig(method.avg_wbits(4096, 4096), 4),
            fmt_sig(rep.ppl_of("c4s"), 4),
            fmt_sig(rep.ppl_of("wiki2s"), 4),
            fmt_sig(rep.ppl_of("ptbs"), 4),
            format!("{:.2}%", 100.0 * rep.avg_qa),
        ]);
        fig1.push((name.to_string(), rep.mean_rel_ppl(&fp)));
        eprintln!("[table1] {name} done");
    }

    println!("\n== Table 1 (tiny GPT; W-bits@7B = storage model at LLaMA-7B dims) ==");
    t1.print();

    println!("\n== Figure 1: average relative perplexity (normalized to FP) ==");
    let max_rel = fig1.iter().map(|(_, r)| *r).fold(1.0f64, f64::max);
    let mut tf = Table::new(&["method", "rel-PPL", "bar"]);
    for (name, rel) in &fig1 {
        let width = ((rel / max_rel) * 40.0).round() as usize;
        tf.row(&[name.clone(), fmt_sig(*rel, 3), "#".repeat(width.max(1))]);
    }
    tf.print();
    Ok(())
}
