//! Table 4 reproduction: model storage per method — measured on the tiny
//! GPT and extrapolated to LLaMA-7B/13B dims through the same storage model.
//!
//!     cargo run --release --example table4_memory

use hbllm::pipeline::Session;
use hbllm::quant::{self, storage};
use hbllm::util::bench::Table;

/// The transformer-block matrix dims of a LLaMA-style model.
fn llama_dims(d: usize, dff: usize, layers: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for _ in 0..layers {
        out.extend([(d, d), (d, d), (d, d), (d, d), (dff, d), (d, dff)]);
    }
    out
}

fn main() -> anyhow::Result<()> {
    // (name, dims, fp16-side params: embeddings + norms)
    let models: Vec<(&str, Vec<(usize, usize)>, usize)> = vec![
        ("LLaMA-7B", llama_dims(4096, 11008, 32), 32000 * 4096 * 2 + 70 * 4096),
        ("LLaMA-13B", llama_dims(5120, 13824, 40), 32000 * 5120 * 2 + 90 * 5120),
    ];
    // our tiny model, if artifacts exist
    let tiny = Session::open(&Session::default_root()).ok().map(|s| {
        let cfg = &s.fp_weights().config;
        let dims: Vec<(usize, usize)> = cfg
            .linear_names()
            .iter()
            .map(|n| {
                let m = s.fp_weights().get(n).as_mat();
                (m.cols, m.rows) // paper orientation
            })
            .collect();
        let linear_elems: usize = dims.iter().map(|(a, b)| a * b).sum();
        let fp_side = s.fp_weights().total_elements() - linear_elems;
        ("tiny-GPT".to_string(), dims, fp_side)
    });

    let mut t = Table::new(&["method", "tiny-GPT", "LLaMA-7B", "LLaMA-13B"]);
    let mut methods: Vec<(&str, Box<dyn Fn(usize, usize) -> f64>)> = vec![
        ("FP16", Box::new(|_, _| 16.0)),
        ("BiLLM", Box::new(|n, m| storage::billm_bits(n, m, 128).per_weight(n, m))),
        ("ARB-LLM_X", Box::new(|n, m| storage::arb_x_bits(n, m, 128).per_weight(n, m))),
        ("ARB-LLM_RC", Box::new(|n, m| storage::arb_rc_bits(n, m, 128).per_weight(n, m))),
        ("PB-LLM", Box::new(|n, m| storage::pbllm_bits(n, m).per_weight(n, m))),
        ("FrameQuant", Box::new(|n, m| storage::framequant_bits(n, m, 1.1).per_weight(n, m))),
    ];
    for name in ["hbllm-row", "hbllm-col"] {
        let q = quant::by_name(name).unwrap();
        let label: &'static str = if name == "hbllm-row" { "HBLLM-row" } else { "HBLLM-col" };
        methods.push((label, Box::new(move |n, m| q.avg_wbits(n, m))));
    }

    for (name, wbits) in &methods {
        let mut row = vec![name.to_string()];
        match &tiny {
            Some((_, dims, fp_side)) => {
                let gb = storage::model_storage_gb(dims, |n, m| wbits(n, m), *fp_side);
                row.push(format!("{:.2}MB", gb * 1000.0));
            }
            None => row.push("-".into()),
        }
        for (_, dims, fp_side) in &models {
            let gb = storage::model_storage_gb(dims, |n, m| wbits(n, m), *fp_side);
            row.push(format!("{gb:.2}GB"));
        }
        t.row(&row);
    }
    println!("== Table 4: model storage (storage model; fp16 embeddings/norms included) ==");
    t.print();
    println!("\npaper shape: HBLLM-col < ARB-RC ≈ BiLLM ≈ PB-LLM < HBLLM-row < ARB-X ≪ FrameQuant ≪ FP16");
    Ok(())
}
