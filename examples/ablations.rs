//! Table 2 ablations (+ our extras) on the tiny GPT:
//!   2a  salient selection criterion (ℓ1 vs ℓ2)
//!   2b  grouping granularity (global vs row-wise)
//!   2c  shared mean (off vs on)
//!   2d  partition candidates (10/20/40/80)
//!   extras: scale scope (Block vs RowGlobal), Haar levels (1 vs 2),
//!           OBQ error propagation via identity-Hessian comparison
//!
//!     cargo run --release --example ablations [-- --which 2a] [-- --quick]

use hbllm::calib::CtxMap;
use hbllm::engine::BackendKind;
use hbllm::coordinator::{quantize_model, QuantJobConfig};
use hbllm::model::Weights;
use hbllm::pipeline::{EvalScope, Session};
use hbllm::quant::grouping::Granularity;
use hbllm::quant::hbllm::{Hbllm, HbllmOpts, ScaleScope, Variant};
use hbllm::quant::salient::Criterion;
use hbllm::util::bench::Table;
use hbllm::util::cli::Args;
use hbllm::util::fmt_sig;

/// All ablation rows score through the XLA backend (the native engine is
/// exercised by the decode bench and parity tests).
const XLA: BackendKind = BackendKind::Xla { pallas: false };

struct Ctx {
    session: Session,
    scope: EvalScope,
    job: QuantJobConfig,
}

impl Ctx {
    /// quantize + eval wiki2s/ptbs PPL (the columns Table 2 reports)
    fn run(&mut self, label: &str, variant: Variant, f: impl Fn(&mut HbllmOpts)) -> anyhow::Result<[String; 3]> {
        let mut opts = HbllmOpts::default();
        f(&mut opts);
        let q = Hbllm::with_opts(variant, opts);
        let (qw, _) = self.session.quantize(&q, &self.scope, &self.job)?;
        let mut be = self.session.backend(&qw, XLA)?;
        let wiki = hbllm::eval::perplexity(be.as_mut(), &self.session.corpus("wiki2s")?, self.scope.ppl_windows)?;
        let ptb = hbllm::eval::perplexity(be.as_mut(), &self.session.corpus("ptbs")?, self.scope.ppl_windows)?;
        eprintln!("[ablate] {label}: wiki2s {wiki:.3} ptbs {ptb:.3}");
        Ok([label.to_string(), fmt_sig(wiki, 4), fmt_sig(ptb, 4)])
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let session = Session::open(&Session::default_root())?;
    let scope = if args.has_flag("quick") {
        EvalScope { ppl_windows: 12, qa_items: 4, calib_windows: 8 }
    } else {
        EvalScope { ppl_windows: 32, qa_items: 8, calib_windows: 16 }
    };
    let which = args.get_or("which", "all").to_string();
    let mut ctx = Ctx { session, scope, job: QuantJobConfig { quiet: true, ..Default::default() } };

    let run_sec = |s: &str| which == "all" || which == s;

    if run_sec("2a") {
        let mut t = Table::new(&["criterion (method)", "wiki2s", "ptbs"]);
        for (v, vn) in [(Variant::Row, "row"), (Variant::Col, "col")] {
            for (c, cn) in [(Criterion::L1, "l1"), (Criterion::L2, "l2")] {
                t.row(&ctx.run(&format!("{cn} ({vn})"), v, |o| o.criterion = c)?);
            }
        }
        println!("\n== Table 2a: salient column selection criterion ==");
        t.print();
    }

    if run_sec("2b") {
        let mut t = Table::new(&["granularity (method)", "wiki2s", "ptbs"]);
        for (v, vn) in [(Variant::Row, "row"), (Variant::Col, "col")] {
            for (g, gn) in [(Granularity::Global, "global"), (Granularity::RowWise, "row-wise")] {
                t.row(&ctx.run(&format!("{gn} ({vn})"), v, |o| o.granularity = g)?);
            }
        }
        println!("\n== Table 2b: grouping granularity ==");
        t.print();
    }

    if run_sec("2c") {
        let mut t = Table::new(&["shared mean (method)", "wiki2s", "ptbs"]);
        for (v, vn) in [(Variant::Row, "row"), (Variant::Col, "col")] {
            for (s, sn) in [(false, "off"), (true, "on")] {
                t.row(&ctx.run(&format!("{sn} ({vn})"), v, |o| o.shared_mean = s)?);
            }
        }
        println!("\n== Table 2c: intra-band shared mean ==");
        t.print();
    }

    if run_sec("2d") {
        let mut t = Table::new(&["candidates", "wiki2s", "ptbs"]);
        for n in [10usize, 20, 40, 80] {
            t.row(&ctx.run(&format!("{n}"), Variant::Row, |o| o.n_candidates = n)?);
        }
        println!("\n== Table 2d: partition candidate count (HBLLM-row) ==");
        t.print();
    }

    if run_sec("scope") {
        let mut t = Table::new(&["scale scope", "wiki2s", "ptbs"]);
        t.row(&ctx.run("RowGlobal (paper bits)", Variant::Row, |o| o.scale_scope = ScaleScope::RowGlobal)?);
        t.row(&ctx.run("Block (fp16/block)", Variant::Row, |o| o.scale_scope = ScaleScope::Block)?);
        println!("\n== Extra: scale scope (storage/fidelity trade, DESIGN.md) ==");
        t.print();
    }

    if run_sec("levels") {
        let mut t = Table::new(&["haar levels", "wiki2s", "ptbs"]);
        for l in [1usize, 2] {
            t.row(&ctx.run(&format!("{l}"), Variant::Row, |o| o.levels = l)?);
        }
        println!("\n== Extra: multi-level Haar (paper future work) ==");
        t.print();
    }

    if run_sec("group-encoding") {
        let mut t = Table::new(&["group encoding", "wiki2s", "ptbs"]);
        t.row(&ctx.run("deployable (shared order)", Variant::Row, |_| {})?);
        t.row(&ctx.run("oracle (+1 bit bitmap)", Variant::Row, |o| o.oracle_grouping = true)?);
        println!("\n== Extra: deployable vs oracle group encoding (DESIGN.md) ==");
        t.print();
    }

    if run_sec("salient-k") {
        let mut t = Table::new(&["salient K", "wiki2s", "ptbs"]);
        t.row(&ctx.run("searched (paper)", Variant::Row, |_| {})?);
        for k in [0usize, 4, 16] {
            t.row(&ctx.run(&format!("fixed {k}"), Variant::Row, |o| {
                o.search_salient_k = false;
                o.fixed_k = k;
            })?);
        }
        println!("\n== Extra: salient column count K ==");
        t.print();
    }

    if run_sec("calib") {
        // calibration-sample sweep: rebuild contexts per setting
        let mut t = Table::new(&["calib windows", "wiki2s", "ptbs"]);
        for n in [4usize, 8, 16] {
            let mut fresh = Session::open(&Session::default_root())?;
            let mut sc = ctx.scope;
            sc.calib_windows = n;
            let q = Hbllm::row();
            let (qw, _) = fresh.quantize(&q, &sc, &ctx.job)?;
            let mut be = fresh.backend(&qw, XLA)?;
            let wiki = hbllm::eval::perplexity(be.as_mut(), &fresh.corpus("wiki2s")?, sc.ppl_windows)?;
            let ptb = hbllm::eval::perplexity(be.as_mut(), &fresh.corpus("ptbs")?, sc.ppl_windows)?;
            t.row(&[format!("{n}"), fmt_sig(wiki, 4), fmt_sig(ptb, 4)]);
            eprintln!("[ablate] calib {n}: {wiki:.3}/{ptb:.3}");
        }
        println!("\n== Extra: calibration sample count ==");
        t.print();
    }

    if run_sec("obq") {
        // OBQ on/off: identity Hessian removes both saliency signal and
        // error propagation
        let mut t = Table::new(&["hessian", "wiki2s", "ptbs"]);
        t.row(&ctx.run("calibrated (OBQ)", Variant::Row, |_| {})?);
        {
            let q = Hbllm::row();
            let identity = CtxMap::identity_for(ctx.session.fp_weights());
            let mut w: Weights = ctx.session.clone_weights();
            quantize_model(&mut w, &identity, &q, &ctx.job)?;
            let mut be = ctx.session.backend(&w, XLA)?;
            let wiki = hbllm::eval::perplexity(be.as_mut(), &ctx.session.corpus("wiki2s")?, ctx.scope.ppl_windows)?;
            let ptb = hbllm::eval::perplexity(be.as_mut(), &ctx.session.corpus("ptbs")?, ctx.scope.ppl_windows)?;
            t.row(&["identity (no calib)".into(), fmt_sig(wiki, 4), fmt_sig(ptb, 4)]);
        }
        println!("\n== Extra: calibration / OBQ contribution ==");
        t.print();
    }

    Ok(())
}
