//! §3.1 CIQ (cardinality of the inverse-quantization set) reproduction:
//! empirical CIQ per method vs the paper's theoretical bounds
//! (BiLLM 8, ARB-LLM_X ~10, ARB-RC up to block size, HBLLM up to 1024).
//!
//!     cargo run --release --example ciq_table

use hbllm::quant::{by_name, ciq, synth};
use hbllm::util::bench::Table;

fn main() {
    let (w, ctx) = synth::llm_like_layer(128, 128, 7); // one β=128 block
    let mut t = Table::new(&["method", "CIQ max", "CIQ mean", "paper bound"]);
    for name in ["rtn", "billm", "arb-x", "arb-rc", "hbllm-col", "hbllm-row"] {
        let q = by_name(name).unwrap();
        let out = q.quantize(&w, &ctx);
        let bound = ciq::theoretical_bound(name, 128);
        t.row(&[
            name.into(),
            format!("{}", ciq::row_ciq_max(&out.w_hat)),
            format!("{:.1}", ciq::row_ciq_mean(&out.w_hat)),
            if bound == usize::MAX { "-".into() } else { format!("{bound}") },
        ]);
    }
    println!("== CIQ expressiveness (single 128-column block, synthetic layer) ==");
    t.print();
    println!("\nHBLLM's Haar butterfly mixes (lo, hi) coefficient pairs, so the");
    println!("dequantized-value set grows multiplicatively — the §3.1 argument.");
}
