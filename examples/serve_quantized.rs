//! Serving demo: quantize the tiny GPT with HBLLM-row, start the
//! continuous-batching TCP server, fire concurrent clients mixing scoring
//! (`ppl`) and streamed generation (`gen`) traffic at it, and report
//! scoring latency percentiles plus generation throughput.
//! `--backend native` serves straight from the packed 1-bit engine with
//! multi-lane KV decoding; `--lanes` sets the lane count and
//! `--kv-blocks`/`--block-len` size the paged KV arena (default: worst
//! case — shrink it to watch admission backpressure under load).
//!
//! `--spec-k N` turns on frequency-cascade speculative decoding for
//! greedy generation requests (Haar low-band draft, full-model verify).
//!
//! `--http-clients N` (default 2) additionally serves the HTTP/SSE
//! front-end from the same engine loop and streams N greedy generations
//! through `POST /v1/generate` with alternating interactive/batch
//! priorities, then snapshots `GET /v1/stats` — the TCP and HTTP clients
//! contend for the same lanes and KV blocks.
//!
//!     cargo run --release --example serve_quantized [-- --requests 64] [-- --clients 8] [-- --backend native] [-- --lanes 4] [-- --kv-blocks 16] [-- --spec-k 4] [-- --http-clients 2]

use hbllm::coordinator::{http, serve, BatcherConfig, Priority, QuantJobConfig};
use hbllm::engine::{Backend, BackendKind, SpecConfig};
use hbllm::pipeline::{EvalScope, Session};
use hbllm::quant;
use hbllm::util::cli::Args;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

const GEN_TOKENS: usize = 24;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n_requests = args.get_usize("requests", 64);
    let n_clients = args.get_usize("clients", 8);
    let lanes = args.get_usize("lanes", 4);
    let kind = BackendKind::parse(args.get_or("backend", "xla"), false, true)?;

    let mut session = Session::open(&Session::default_root())?;
    let scope = EvalScope { ppl_windows: 4, qa_items: 4, calib_windows: 8 };
    let method = quant::by_name("hbllm-row").unwrap();
    eprintln!("quantizing with hbllm-row...");
    let (qw, _) = session.quantize(
        method.as_ref(),
        &scope,
        &QuantJobConfig { quiet: true, ..Default::default() },
    )?;
    let kv_blocks = args.get("kv-blocks").and_then(|v| v.parse().ok());
    let block_len = args.get("block-len").and_then(|v| v.parse().ok());
    let mut backend = session.serve_backend(&qw, kind, lanes, kv_blocks, block_len)?;
    // `--spec-k N` drafts with the Haar low band on greedy requests (the
    // sampling clients below stay on the plain path automatically)
    let spec = backend.set_spec(SpecConfig::with_k(args.get_usize("spec-k", 0)));

    // request corpus: lines from wiki2s
    let corpus = session.corpus("wiki2s")?;
    let lines: Vec<String> = String::from_utf8_lossy(&corpus.data)
        .lines()
        .filter(|l| l.len() > 20)
        .take(n_requests)
        .map(String::from)
        .collect();

    let n_http = args.get_usize("http-clients", 2);
    let (listener, addr) = serve::bind("127.0.0.1:0")?;
    let (http_listener, http_addr) = serve::bind("127.0.0.1:0")?;
    let http_url = format!("http://{http_addr}");
    eprintln!(
        "serving on {addr} (http {http_addr}) [backend {}, {} lanes]; {n_clients} clients x {} score requests + 1 gen request each, {n_http} http/sse streams",
        backend.name(),
        backend.lanes(),
        lines.len()
    );

    let t0 = Instant::now();
    // each client scores its share of the corpus, then streams one
    // generation — so scoring batches and generation lanes are exercised
    // concurrently
    let clients: Vec<std::thread::JoinHandle<(Vec<Duration>, usize)>> = (0..n_clients)
        .map(|c| {
            let lines = lines.clone();
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                let mut lat = Vec::new();
                for (i, line) in lines.iter().enumerate() {
                    if i % n_clients != c {
                        continue;
                    }
                    let t = Instant::now();
                    stream.write_all(format!("ppl {line}\n").as_bytes()).unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    assert!(resp.starts_with("ppl "), "bad response {resp}");
                    lat.push(t.elapsed());
                }
                stream
                    .write_all(format!("gen {GEN_TOKENS} 0.8 {c} ta kivo remo \n").as_bytes())
                    .unwrap();
                let mut toks = 0usize;
                loop {
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    let resp = resp.trim_end();
                    if resp.starts_with("tok ") {
                        toks += 1;
                    } else {
                        assert!(resp.starts_with("done "), "bad terminator {resp}");
                        break;
                    }
                }
                (lat, toks)
            })
        })
        .collect();

    // HTTP/SSE streams contend with the TCP clients for the same lanes;
    // priorities alternate so both admission tiers see traffic, and the
    // first client snapshots /v1/stats while the service is live
    let http_clients: Vec<std::thread::JoinHandle<(usize, Option<String>)>> = (0..n_http)
        .map(|c| {
            let url = http_url.clone();
            std::thread::spawn(move || {
                let prio = if c % 2 == 0 { Priority::Interactive } else { Priority::Batch };
                let mut toks = 0usize;
                let n = http::client_generate(
                    &url,
                    "ta kivo remo ",
                    GEN_TOKENS,
                    0.0,
                    c as u64,
                    prio,
                    |_| toks += 1,
                )
                .expect("http generation failed");
                assert_eq!(n, toks, "sse done count disagrees with streamed tokens");
                let stats = (c == 0).then(|| {
                    http::client_stats(&url).expect("stats fetch failed").to_string()
                });
                (toks, stats)
            })
        })
        .collect();

    let mut fronts = vec![serve::FrontEnd::line(listener, Some(n_clients))];
    if n_http > 0 {
        // one extra connection for the stats snapshot
        fronts.push(http::HttpConn::front_end(http_listener, Some(n_http + 1)));
    }
    serve::serve_fronts(fronts, backend.as_mut(), BatcherConfig { spec, ..Default::default() })?;
    let mut lats: Vec<Duration> = Vec::new();
    let mut gen_tokens = 0usize;
    for c in clients {
        let (lat, toks) = c.join().unwrap();
        lats.extend(lat);
        gen_tokens += toks;
    }
    let mut http_tokens = 0usize;
    let mut stats_line = None;
    for c in http_clients {
        let (toks, stats) = c.join().unwrap();
        http_tokens += toks;
        stats_line = stats_line.or(stats);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort();
    println!("\n== serving results (quantized model, scoring + generation) ==");
    println!("score reqs : {}", lats.len());
    println!("gen tokens : {gen_tokens} ({n_clients} tcp streams x {GEN_TOKENS})");
    if n_http > 0 {
        println!("http tokens: {http_tokens} ({n_http} sse streams x {GEN_TOKENS}, mixed priorities)");
        if let Some(stats) = stats_line {
            println!("live stats : {stats}");
        }
    }
    println!(
        "throughput : {:.1} req/s (scores+gens over {wall:.2}s wall)",
        (lats.len() + n_clients + n_http) as f64 / wall
    );
    if !lats.is_empty() {
        let q = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize].as_secs_f64() * 1e3;
        println!("latency    : p50 {:.1}ms  p90 {:.1}ms  p99 {:.1}ms (scoring)", q(0.5), q(0.9), q(0.99));
    }
    Ok(())
}
