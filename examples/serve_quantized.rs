//! Serving demo: quantize the tiny GPT with HBLLM-row, start the batched
//! TCP scoring server, fire concurrent clients at it, and report
//! latency/throughput percentiles. `--backend native` serves straight from
//! the packed 1-bit engine instead of the PJRT/XLA runner.
//!
//!     cargo run --release --example serve_quantized [-- --requests 64] [-- --clients 8] [-- --backend native]

use hbllm::coordinator::{serve, BatcherConfig, QuantJobConfig};
use hbllm::engine::{Backend, BackendKind};
use hbllm::pipeline::{EvalScope, Session};
use hbllm::quant;
use hbllm::util::cli::Args;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n_requests = args.get_usize("requests", 64);
    let n_clients = args.get_usize("clients", 8);
    let kind = BackendKind::parse(args.get_or("backend", "xla"), false, true)?;

    let mut session = Session::open(&Session::default_root())?;
    let scope = EvalScope { ppl_windows: 4, qa_items: 4, calib_windows: 8 };
    let method = quant::by_name("hbllm-row").unwrap();
    eprintln!("quantizing with hbllm-row...");
    let (qw, _) = session.quantize(method.as_ref(), &scope, &QuantJobConfig { quiet: true, ..Default::default() })?;
    let mut backend = session.backend(&qw, kind)?;

    // request corpus: lines from wiki2s
    let corpus = session.corpus("wiki2s")?;
    let lines: Vec<String> = String::from_utf8_lossy(&corpus.data)
        .lines()
        .filter(|l| l.len() > 20)
        .take(n_requests)
        .map(String::from)
        .collect();

    let (listener, addr) = serve::bind("127.0.0.1:0")?;
    eprintln!(
        "serving on {addr} [backend {}]; {n_clients} clients x {} requests",
        backend.name(),
        lines.len()
    );

    let t0 = Instant::now();
    let clients: Vec<std::thread::JoinHandle<Vec<Duration>>> = (0..n_clients)
        .map(|c| {
            let lines = lines.clone();
            std::thread::spawn(move || {
                let mut lat = Vec::new();
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                for (i, line) in lines.iter().enumerate() {
                    if i % n_clients != c {
                        continue;
                    }
                    let t = Instant::now();
                    stream.write_all(line.as_bytes()).unwrap();
                    stream.write_all(b"\n").unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    assert!(resp.starts_with("ppl "), "bad response {resp}");
                    lat.push(t.elapsed());
                }
                lat
            })
        })
        .collect();

    serve::serve_on(listener, backend.as_mut(), BatcherConfig::default(), Some(n_clients))?;
    let mut lats: Vec<Duration> = Vec::new();
    for c in clients {
        lats.extend(c.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort();
    let q = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize].as_secs_f64() * 1e3;
    println!("\n== serving results (batched scoring of quantized model) ==");
    println!("requests   : {}", lats.len());
    println!("throughput : {:.1} req/s", lats.len() as f64 / wall);
    println!("latency    : p50 {:.1}ms  p90 {:.1}ms  p99 {:.1}ms", q(0.5), q(0.9), q(0.99));
    Ok(())
}
